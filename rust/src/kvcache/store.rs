//! The RAM document-cache tiers: shared host tier + per-engine
//! residency tier (see the [`super`] module docs for the full diagram
//! and the pin-guard contract; the persistent tier beneath them is
//! [`super::disk`], the storage substrate beneath both RAM tiers is
//! the paged [`super::pool`]).
//!
//! [`HostDocCache`] is the process-wide, thread-safe, content-addressed
//! tier: one entry per unique document (FNV-1a over token ids), shared
//! by every engine behind an `Arc`. Entry KV lives as fixed-size blocks
//! in the host's [`KvBlockPool`] slab, so eviction is **block-granular**:
//! going over budget sheds a document's cold tail blocks first, and the
//! partially evicted document keeps serving warm hits for its resident
//! blocks. A miss hands the caller a [`PrefillLease`] so each unique
//! document is prefilled **exactly once process-wide** — concurrent
//! engines asking for the same in-flight document block until the lease
//! publishes (or is abandoned on error); a lease over a *partially*
//! evicted entry carries the entry ([`PrefillLease::partial`]) so the
//! holder refills only the missing blocks (from disk, else a prefill)
//! instead of rebuilding the document. With a [`DiskDocCache`] attached
//! ([`HostDocCache::with_disk`]), evicted blocks are spilled to disk
//! per-block instead of dropped (writeback mode per [`DiskWriteback`]).
//!
//! [`EngineDocCache`] is one engine's residency tier: the subset of
//! host entries "device-resident" for that engine (its own byte budget
//! and LRU clock), consulted first; misses fall through to the host
//! tier, and fresh prefills are published back so one engine's work is
//! every engine's hit. Residency holds `Arc`s into the same pooled
//! entries (no copies), so its eviction stays doc-granular: dropping a
//! resident ref never frees pool slots the host still holds.
//!
//! # Hash-collision safety
//!
//! Every tier keys on the FNV-1a content hash, so every by-hash hit
//! **verifies the stored token ids against the requested document**
//! before serving: a mismatch (two documents colliding on one hash) is
//! counted in [`CacheStats::hash_collisions`] and treated as a miss —
//! the colliding prefill then *replaces* the stored entry (reinsert
//! accounting) rather than silently serving another document's KV.
//!
//! # Stats counters: lifetime vs. current
//!
//! [`CacheStats`] mixes two kinds of counters. **Lifetime** counters
//! only grow and survive [`clear`](EngineDocCache::clear): `hits`,
//! `misses`, `evictions` (whole-entry removals — block-level counts
//! live in [`super::pool::PoolStats`]), `publishes`, `reinserts`
//! (which also counts block refills of a partially evicted entry),
//! `hash_collisions`, and `peak_bytes` (the high-water mark).
//! **Current** state — `current_bytes` — tracks the bytes resident
//! right now and resets to zero on `clear`.
//! [`EngineDocCache::reset_stats`] / [`HostDocCache::reset_stats`]
//! zero the lifetime counters too (peak collapses to the current
//! footprint).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;

use crate::config::DiskWriteback;
use crate::model::{Model, PrefillDocOut};
use crate::sync::{Condvar, Mutex};
use crate::tensor::Tensor;

use super::codec::KvCodec;
use super::disk::{self as disk_mod, DiskDocCache};
use super::evict::{EvictionCandidate, EvictionPolicy, LruPolicy,
                   WHOLE_ENTRY};
use super::pool::{KvBlockPool, KvBlocks, DEFAULT_KV_BLOCK_TOKENS};
use super::residency::ResidencyHandle;

/// Block index meaning "every block of the document" in a pin key —
/// session pins pin whole documents (dynamic sparse selection may read
/// any block mid-decode).
pub const PIN_ALL: u32 = u32::MAX;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over raw bytes — one definition shared by the content hash
/// below and the disk tier's checksums, so the two can never drift
/// apart.
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over token ids (little-endian bytes) — the document cache
/// key. Streams per token instead of materializing a byte buffer, but
/// is bit-identical to [`fnv64`] over the concatenated `to_le_bytes`.
pub fn doc_hash(tokens: &[i32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// A cached document: prefill outputs + bookkeeping. Shared across
/// engine threads (and with in-flight sessions) via `Arc`, so eviction
/// from either tier never invalidates a live assemble. The KV lives as
/// refcounted blocks in the host's pool ([`KvBlocks`]) — blocks may be
/// individually evicted and restored while the entry stays shared.
#[derive(Debug)]
pub struct DocEntry {
    pub hash: u64,
    pub tokens: Vec<i32>,
    /// `[L, 2, H, Ld, Dh]` worth of local (position 0-based) RoPE KV,
    /// stored as pool blocks of `--kv-block-tokens` tokens each.
    pub kv: KvBlocks,
    /// `[L, H, Ld, Ld]` attention probabilities.
    pub attn: Tensor,
    /// `[L, H, Dh]` local-window mean Q (Eq. 1 bias source).
    pub q_local: Tensor,
    /// **Physical** size of the *complete* entry (all blocks resident)
    /// at construction: cold blocks past the codec hot watermark count
    /// at their encoded size, so budgets under a lossy codec hold
    /// proportionally more documents. Equals the logical size under the
    /// default f32 codec.
    pub bytes: usize,
}

impl DocEntry {
    /// Pool-backed entry from a prefill output.
    pub fn new(pool: &Arc<KvBlockPool>, tokens: Vec<i32>,
               out: PrefillDocOut) -> Result<DocEntry> {
        Self::from_parts(pool, tokens, out.kv, out.attn, out.q_local)
    }

    /// Pool-backed entry from raw tensors (disk decode, tests).
    pub fn from_parts(pool: &Arc<KvBlockPool>, tokens: Vec<i32>,
                      kv: Tensor, attn: Tensor, q_local: Tensor)
                      -> Result<DocEntry> {
        let kv = KvBlocks::from_tensor(pool, &kv)?;
        // physical bytes: fresh entries are fully resident, so this is
        // the encoded-aware footprint of the whole document
        let bytes =
            kv.resident_bytes() + attn.size_bytes() + q_local.size_bytes();
        Ok(DocEntry {
            hash: doc_hash(&tokens),
            tokens,
            kv,
            attn,
            q_local,
            bytes,
        })
    }
}

/// **Physical** bytes of this entry currently resident in RAM:
/// resident KV blocks (encoded blocks at payload size) plus the (never
/// block-split) attn/q_local side tensors — what the byte budgets
/// charge.
fn entry_resident_bytes(e: &DocEntry) -> usize {
    e.kv.resident_bytes() + e.attn.size_bytes() + e.q_local.size_bytes()
}

/// Per-tier counters. Lifetime counters (`hits`, `misses`,
/// `evictions`, `publishes`, `reinserts`, `hash_collisions`,
/// `peak_bytes`) survive `clear`; `current_bytes` is current state and
/// resets with the entries (see the module docs).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Whole-entry removals. Individual block evictions are counted in
    /// [`super::pool::PoolStats::blocks_evicted`].
    pub evictions: u64,
    /// Entries inserted: host tier — published prefills; residency
    /// tier — admissions (fresh prefills and host-tier promotions).
    pub publishes: u64,
    /// Inserts that replaced an entry already present under the same
    /// hash (the old entry's bytes are subtracted, never leaked), and
    /// block refills of a partially evicted entry.
    pub reinserts: u64,
    /// By-hash hits whose stored token ids did not match the requested
    /// document (content-hash collision) — served as misses, never as
    /// another document's KV (see the module docs).
    pub hash_collisions: u64,
    pub current_bytes: usize,
    pub peak_bytes: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    fn note_insert(&mut self, bytes: usize, replaced: Option<usize>) {
        if let Some(old) = replaced {
            self.current_bytes -= old;
            self.reinserts += 1;
        }
        self.current_bytes += bytes;
        self.publishes += 1;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    fn reset_lifetime(&mut self) {
        let current = self.current_bytes;
        *self = CacheStats { current_bytes: current,
                             peak_bytes: current,
                             ..CacheStats::default() };
    }
}

// ---------------------------------------------------------------------------
// Host tier
// ---------------------------------------------------------------------------

struct HostSlot {
    entry: Arc<DocEntry>,
    last_use: u64,
    /// Bytes of the entry resident right now (block eviction shrinks
    /// this without removing the entry). Only mutated under the host
    /// lock.
    resident_bytes: usize,
}

struct HostInner {
    entries: HashMap<u64, HostSlot>,
    /// Hashes currently being prefilled/refilled under a
    /// [`PrefillLease`].
    in_flight: HashSet<u64>,
    /// Pin counts per `(hash, block)` key; block [`PIN_ALL`] pins the
    /// whole document. A hash may be pinned before it exists.
    pins: HashMap<(u64, u32), u32>,
    clock: u64,
    budget_bytes: usize,
    /// True when the budget was fixed by the operator/caller;
    /// auto-sized tiers let engines raise it from model geometry.
    budget_explicit: bool,
    stats: CacheStats,
}

impl HostInner {
    fn block_pinned(&self, hash: u64, block: u32) -> bool {
        self.pins.contains_key(&(hash, PIN_ALL))
            || self.pins.contains_key(&(hash, block))
    }
}

/// One evicted block on its way to the disk tier: the payload is
/// extracted **under the host lock** (before the slot is reused) and
/// written outside it.
struct Spill {
    entry: Arc<DocEntry>,
    block: u32,
    data: Vec<f32>,
}

/// Result of [`HostDocCache::lookup_or_begin`].
pub enum HostLookup {
    /// The entry is cached and fully resident; use it.
    Hit(Arc<DocEntry>),
    /// Nobody holds this document complete: the caller must prefill
    /// (or refill — see [`PrefillLease::partial`]) and publish the
    /// result (dropping the lease without publishing abandons it,
    /// waking any waiters to retry).
    Miss(PrefillLease),
}

/// Cluster peer access for the host tier, implemented by
/// `crate::server::peers::ClusterPeers`. Lives here (not in the
/// server layer) so the cache hierarchy can consult peers under the
/// prefill lease without a kvcache → server dependency.
///
/// The fetch side of the multi-node degradation contract: `fetch`
/// must map **every** failure — dead peer, timeout, truncation,
/// injected fault, honest miss — to `None`, which the caller treats
/// exactly like a disk miss (fall through to the local model
/// prefill).
pub trait PeerFetcher: Send + Sync {
    /// True when another node owns this document hash (consistent
    /// hashing) — the only case a fetch is attempted.
    fn owner_is_remote(&self, hash: u64) -> bool;

    /// Ask the owning peer for the serialized entry image (the disk
    /// v3 wire format, see [`super::disk::entry_from_bytes`]).
    fn fetch(&self, hash: u64, tokens: &[i32]) -> Option<Vec<u8>>;
}

/// The shared host tier: thread-safe, content-addressed document cache
/// with a byte budget, block-granular pluggable eviction over a
/// [`KvBlockPool`], pin guards, exactly-once prefill leasing, an
/// optional persistent [`DiskDocCache`] tier beneath it (per-block
/// spill on eviction / write-through per [`DiskWriteback`]), and an
/// optional cluster [`PeerFetcher`] beside the disk tier (`--peers`).
pub struct HostDocCache {
    inner: Mutex<HostInner>,
    published: Condvar,
    policy: Box<dyn EvictionPolicy>,
    disk: Option<DiskTier>,
    peers: Option<Arc<dyn PeerFetcher>>,
    pool: Arc<KvBlockPool>,
}

struct DiskTier {
    cache: Arc<DiskDocCache>,
    writeback: DiskWriteback,
}

impl HostDocCache {
    pub fn new(budget_bytes: usize) -> HostDocCache {
        Self::with_policy(budget_bytes, Box::new(LruPolicy))
    }

    pub fn with_policy(budget_bytes: usize,
                       policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        Self::build(budget_bytes, true, policy)
    }

    /// Auto-sized tier: starts with a zero budget that engines raise
    /// via [`Self::ensure_min_budget`] once their model geometry is
    /// known — bounded by default without the caller having to guess
    /// KV sizes up front.
    pub fn auto_sized(policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        Self::build(0, false, policy)
    }

    fn build(budget_bytes: usize, budget_explicit: bool,
             policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        HostDocCache {
            inner: Mutex::named("host-inner", HostInner {
                entries: HashMap::new(),
                in_flight: HashSet::new(),
                pins: HashMap::new(),
                clock: 0,
                budget_bytes,
                budget_explicit,
                stats: CacheStats::default(),
            }),
            published: Condvar::new(),
            policy,
            disk: None,
            peers: None,
            pool: Arc::new(KvBlockPool::new(DEFAULT_KV_BLOCK_TOKENS)),
        }
    }

    /// Set the KV block size (`--kv-block-tokens`). Builder-style:
    /// must be called before any entry is stored (it replaces the
    /// backing pool, keeping any codec already configured).
    pub fn with_block_tokens(mut self, block_tokens: usize)
                             -> HostDocCache {
        let codec = Arc::clone(self.pool.codec());
        let hot = self.pool.hot_blocks();
        self.pool = Arc::new(
            KvBlockPool::new(block_tokens.max(1)).with_codec(codec, hot));
        self
    }

    /// Set the KV block codec and hot watermark (`--kv-codec` /
    /// `--kv-hot-blocks`): per-document blocks `>= hot_blocks` are
    /// stored encoded when the codec is lossy, and budgets charge the
    /// encoded size. Builder-style: must be called before any entry is
    /// stored. Share the same codec `Arc` with the disk tier so its
    /// stats aggregate across tiers.
    pub fn with_codec(mut self, codec: Arc<dyn KvCodec>,
                      hot_blocks: usize) -> HostDocCache {
        self.pool = Arc::new(
            KvBlockPool::new(self.pool.block_tokens())
                .with_codec(codec, hot_blocks));
        self
    }

    /// The backing KV block pool (shared with every entry).
    pub fn pool(&self) -> &Arc<KvBlockPool> {
        &self.pool
    }

    /// Attach the persistent disk tier. Reads always consult it on a
    /// host miss (under the miss's prefill lease, so each absent
    /// document is loaded from disk at most once process-wide);
    /// `writeback` controls when blocks are written (spill on
    /// eviction, write-through on insert, or never).
    pub fn with_disk(mut self, disk: Arc<DiskDocCache>,
                     writeback: DiskWriteback) -> HostDocCache {
        self.disk = Some(DiskTier { cache: disk, writeback });
        self
    }

    /// The attached persistent tier, if any.
    pub fn disk(&self) -> Option<&Arc<DiskDocCache>> {
        self.disk.as_ref().map(|d| &d.cache)
    }

    /// The attached tier's writeback mode, if any.
    pub fn disk_writeback(&self) -> Option<DiskWriteback> {
        self.disk.as_ref().map(|d| d.writeback)
    }

    /// Attach the cluster peer fetcher (`--peers` mode): a whole-entry
    /// host+disk miss asks the owning peer for the serialized entry —
    /// under the same prefill lease — before paying a model prefill,
    /// making the exactly-once guarantee cluster-wide.
    pub fn with_peers(mut self, peers: Arc<dyn PeerFetcher>)
                      -> HostDocCache {
        self.peers = Some(peers);
        self
    }

    /// The attached peer fetcher, if any.
    pub fn peers(&self) -> Option<&Arc<dyn PeerFetcher>> {
        self.peers.as_ref()
    }

    /// Serve one document to a cluster peer: the serialized **complete**
    /// entry image from this tier (bumping its recency like any hit),
    /// falling through to a complete disk-tier record. `None` — a
    /// partial or absent document — is the peer-miss reply; the asker
    /// degrades to its own prefill, so this never blocks on a lease.
    pub fn export_wire(&self, hash: u64, tokens: &[i32])
                       -> Option<Vec<u8>> {
        if let Some(entry) = self.try_lookup(hash, tokens) {
            if let Some(bytes) =
                disk_mod::entry_to_bytes(&entry, self.pool.codec())
            {
                return Some(bytes);
            }
        }
        let disk = self.disk()?;
        let entry = disk.load(hash, tokens, &self.pool)?;
        if !entry.kv.is_fully_resident() {
            return None;
        }
        disk_mod::entry_to_bytes(&entry, self.pool.codec())
    }

    /// Unbounded tier (eval harness / tests).
    pub fn unbounded() -> HostDocCache {
        Self::new(usize::MAX)
    }

    /// Raise an auto-sized tier's budget to at least `bytes` (engines
    /// call this at init with a budget derived from model geometry).
    /// No-op when the budget was set explicitly, or already larger.
    pub fn ensure_min_budget(&self, bytes: usize) {
        let mut g = self.inner.lock();
        if !g.budget_explicit && g.budget_bytes < bytes {
            g.budget_bytes = bytes;
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().budget_bytes
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.inner.lock().entries.contains_key(&hash)
    }

    /// Fetch-or-lease: a **fully resident** hit bumps recency and
    /// returns the entry; a miss — including a partially evicted entry
    /// — registers the hash as in-flight and returns the lease (with
    /// [`PrefillLease::partial`] set for the refill case). `tokens`
    /// are the requested document's ids — an entry stored under the
    /// hash with *different* tokens is a collision and reads as a miss
    /// (see the module docs). Blocks while another thread holds the
    /// hash's lease (their publish becomes our hit — the exactly-once
    /// contract).
    /// Associated fn (not a method): the lease must hold the `Arc`.
    pub fn lookup_or_begin(host: &Arc<HostDocCache>, hash: u64,
                           tokens: &[i32]) -> HostLookup {
        let mut g = host.inner.lock();
        loop {
            {
                let inner = &mut *g;
                let mut partial = None;
                match inner.entries.get_mut(&hash) {
                    Some(slot) if slot.entry.tokens == tokens => {
                        if slot.entry.kv.is_fully_resident() {
                            inner.clock += 1;
                            slot.last_use = inner.clock;
                            inner.stats.hits += 1;
                            return HostLookup::Hit(
                                Arc::clone(&slot.entry));
                        }
                        // partially evicted: the lease holder refills
                        // just the missing blocks
                        partial = Some(Arc::clone(&slot.entry));
                    }
                    // same hash, different document: fall through to
                    // the miss path — the caller's publish replaces
                    // the colliding entry
                    Some(_) => inner.stats.hash_collisions += 1,
                    None => {}
                }
                if !inner.in_flight.contains(&hash) {
                    inner.stats.misses += 1;
                    inner.in_flight.insert(hash);
                    return HostLookup::Miss(PrefillLease {
                        host: Arc::clone(host),
                        hash,
                        done: false,
                        partial,
                    });
                }
            }
            // someone else holds the lease: wait for their publish (or
            // abandonment) and retry
            g = host.published.wait(g);
        }
    }

    /// Non-leasing lookup (counts a hit or a miss, never blocks).
    /// Collision-checked like [`Self::lookup_or_begin`]; a partially
    /// evicted entry reads as a miss (use [`Self::partial_entry`] to
    /// reach it for a refill).
    pub fn try_lookup(&self, hash: u64, tokens: &[i32])
                      -> Option<Arc<DocEntry>> {
        let mut g = self.inner.lock();
        let inner = &mut *g;
        match inner.entries.get_mut(&hash) {
            Some(slot) if slot.entry.tokens == tokens
                && slot.entry.kv.is_fully_resident() =>
            {
                inner.clock += 1;
                slot.last_use = inner.clock;
                inner.stats.hits += 1;
                Some(Arc::clone(&slot.entry))
            }
            Some(slot) if slot.entry.tokens == tokens => {
                inner.stats.misses += 1; // partial: not servable whole
                None
            }
            Some(_) => {
                inner.stats.hash_collisions += 1;
                inner.stats.misses += 1;
                None
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// The stored entry iff it matches `tokens` and is **partially**
    /// evicted (counter-free — callers refill it and then
    /// [`Self::note_refilled`]).
    pub fn partial_entry(&self, hash: u64, tokens: &[i32])
                         -> Option<Arc<DocEntry>> {
        let g = self.inner.lock();
        let slot = g.entries.get(&hash)?;
        if slot.entry.tokens == tokens
            && !slot.entry.kv.is_fully_resident()
        {
            Some(Arc::clone(&slot.entry))
        } else {
            None
        }
    }

    /// Insert an entry directly (tests / replay / lease-less callers).
    /// Replacing an existing hash subtracts the old entry's resident
    /// bytes — duplicate inserts never inflate the accounting.
    pub fn publish(&self, entry: Arc<DocEntry>) {
        let spills = {
            let mut g = self.inner.lock();
            Self::insert_locked(&mut g, Arc::clone(&entry));
            self.evict_to_budget_locked(&mut g)
        };
        self.published.notify_all();
        self.writeback(Some(&entry), spills);
    }

    /// Complete (or abandon) a lease; called by [`PrefillLease`].
    fn finish_lease(&self, hash: u64, entry: Option<Arc<DocEntry>>) {
        let spills = {
            let mut g = self.inner.lock();
            g.in_flight.remove(&hash);
            match &entry {
                Some(e) => {
                    Self::insert_locked(&mut g, Arc::clone(e));
                    self.evict_to_budget_locked(&mut g)
                }
                None => Vec::new(),
            }
        };
        self.published.notify_all();
        self.writeback(entry.as_ref(), spills);
    }

    /// Complete a partial-refill lease: the entry the lease was issued
    /// over is fully resident again; fix the byte accounting and wake
    /// waiters.
    fn finish_restored(&self, hash: u64) {
        let (entry, spills) = {
            let mut g = self.inner.lock();
            g.in_flight.remove(&hash);
            let entry = Self::note_refilled_locked(&mut g, hash);
            (entry, self.evict_to_budget_locked(&mut g))
        };
        self.published.notify_all();
        self.writeback(entry.as_ref(), spills);
    }

    /// Lease-less refill accounting (prefetch path).
    fn note_refilled(&self, hash: u64) {
        let (entry, spills) = {
            let mut g = self.inner.lock();
            let entry = Self::note_refilled_locked(&mut g, hash);
            (entry, self.evict_to_budget_locked(&mut g))
        };
        self.published.notify_all();
        self.writeback(entry.as_ref(), spills);
    }

    fn note_refilled_locked(g: &mut HostInner, hash: u64)
                            -> Option<Arc<DocEntry>> {
        g.clock += 1;
        let clock = g.clock;
        let slot = g.entries.get_mut(&hash)?;
        let new_rb = entry_resident_bytes(&slot.entry);
        let grown = new_rb.saturating_sub(slot.resident_bytes);
        slot.resident_bytes = new_rb;
        slot.last_use = clock;
        g.stats.current_bytes += grown;
        g.stats.peak_bytes =
            g.stats.peak_bytes.max(g.stats.current_bytes);
        g.stats.reinserts += 1;
        Some(Arc::clone(&slot.entry))
    }

    /// Apply the disk writeback policy after an insert/eviction pass
    /// (outside the host lock — file writes must not stall lookups):
    /// write-through persists the fresh insert immediately; both
    /// write modes persist evicted blocks (spill), grouped per
    /// document so one eviction pass costs at most one file write per
    /// victim document. Write errors are logged and dropped — losing
    /// a spill only costs a future recompute, never correctness.
    fn writeback(&self, inserted: Option<&Arc<DocEntry>>,
                 spills: Vec<Spill>) {
        let Some(d) = &self.disk else { return };
        if d.writeback == DiskWriteback::Off {
            return;
        }
        if d.writeback == DiskWriteback::Through {
            if let Some(e) = inserted {
                if let Err(err) = d.cache.store_blocks(e, &[]) {
                    crate::warn!("disk write-through failed for \
                                  {:016x}: {err:#}", e.hash);
                }
            }
        }
        let mut by_doc: HashMap<u64, (Arc<DocEntry>,
                                      Vec<(u32, Vec<f32>)>)> =
            HashMap::new();
        let mut n_blocks = 0u64;
        for s in spills {
            let slot = by_doc
                .entry(s.entry.hash)
                .or_insert_with(|| (Arc::clone(&s.entry), Vec::new()));
            slot.1.push((s.block, s.data));
            n_blocks += 1;
        }
        for (hash, (entry, blocks)) in by_doc {
            if let Err(err) = d.cache.store_blocks(&entry, &blocks) {
                crate::warn!("disk spill failed for {hash:016x}: \
                              {err:#}");
            }
        }
        if n_blocks > 0 {
            self.pool.note_blocks_spilled(n_blocks);
        }
    }

    fn insert_locked(g: &mut HostInner, entry: Arc<DocEntry>) {
        g.clock += 1;
        let clock = g.clock;
        let hash = entry.hash;
        let resident_bytes = entry_resident_bytes(&entry);
        let replaced = g
            .entries
            .insert(hash, HostSlot { entry, last_use: clock,
                                     resident_bytes })
            .map(|old| old.resident_bytes);
        g.stats.note_insert(resident_bytes, replaced);
    }

    /// Evict down to the byte budget at **block granularity**: the
    /// policy sees one candidate per unpinned resident
    /// `(document, block)` pair, so a cold tail block can leave while
    /// the document's head stays warm; an entry whose last KV block
    /// leaves is removed whole (one `evictions` count). Returns the
    /// evicted block payloads (extracted under the lock, before their
    /// slots can be reused) so the caller can spill them to the disk
    /// tier after the lock drops.
    fn evict_to_budget_locked(&self, g: &mut HostInner) -> Vec<Spill> {
        let mut spills = Vec::new();
        while g.stats.current_bytes > g.budget_bytes
            && g.entries.len() > 1
        {
            // rebuild candidates each round: every eviction changes
            // the residency the next decision must see
            let mut candidates: Vec<EvictionCandidate> = Vec::new();
            for (&h, s) in g.entries.iter() {
                if g.pins.contains_key(&(h, PIN_ALL)) {
                    continue;
                }
                let resident = s.entry.kv.resident_block_indexes();
                if resident.is_empty() {
                    // no KV blocks (a zero-length doc): offer the
                    // whole entry so it stays evictable
                    candidates.push(EvictionCandidate {
                        hash: h,
                        block: WHOLE_ENTRY,
                        bytes: s.resident_bytes,
                        last_use: s.last_use,
                        recompute_cost: s.entry.tokens.len(),
                    });
                    continue;
                }
                for b in resident {
                    if g.pins.contains_key(&(h, b)) {
                        continue;
                    }
                    candidates.push(EvictionCandidate {
                        hash: h,
                        block: b,
                        // physical: an encoded block frees only its
                        // payload bytes
                        bytes: s
                            .entry
                            .kv
                            .block_physical_bytes(b as usize)
                            .unwrap_or(0),
                        last_use: s.last_use,
                        recompute_cost: s.entry.tokens.len(),
                    });
                }
            }
            let Some(c) = self
                .policy
                .pick_victim(&candidates)
                .and_then(|i| candidates.get(i).copied())
            else {
                break; // everything pinned (or policy refused)
            };
            if c.block == WHOLE_ENTRY {
                let Some(slot) = g.entries.remove(&c.hash) else { break };
                g.stats.current_bytes = g
                    .stats
                    .current_bytes
                    .saturating_sub(slot.resident_bytes);
                g.stats.evictions += 1;
                continue;
            }
            let (entry, data, freed) = {
                let Some(slot) = g.entries.get_mut(&c.hash) else {
                    break;
                };
                // physical bytes freed — read before the take empties
                // the slot
                let freed = slot
                    .entry
                    .kv
                    .block_physical_bytes(c.block as usize)
                    .unwrap_or(0);
                let Some(data) =
                    slot.entry.kv.take_block_data(c.block as usize)
                else {
                    break;
                };
                slot.resident_bytes =
                    slot.resident_bytes.saturating_sub(freed);
                (Arc::clone(&slot.entry), data, freed)
            };
            g.stats.current_bytes =
                g.stats.current_bytes.saturating_sub(freed);
            self.pool.note_blocks_evicted(1);
            if entry.kv.resident_block_indexes().is_empty() {
                // the whole KV left RAM: the attn/q_local stubs go too
                if let Some(slot) = g.entries.remove(&c.hash) {
                    g.stats.current_bytes = g
                        .stats
                        .current_bytes
                        .saturating_sub(slot.resident_bytes);
                }
                g.stats.evictions += 1;
            } else {
                self.pool.note_partial_eviction();
            }
            spills.push(Spill { entry, block: c.block, data });
        }
        spills
    }

    /// Any pin (any block) on the hash?
    pub fn is_pinned(&self, hash: u64) -> bool {
        self.inner
            .lock()
            .pins
            .keys()
            .any(|k| k.0 == hash)
    }

    /// Snapshot of every hash with at least one pinned block (one lock
    /// acquisition — for eviction passes that filter many candidates).
    pub fn pinned_hashes(&self) -> HashSet<u64> {
        self.inner
            .lock()
            .pins
            .keys()
            .map(|k| k.0)
            .collect()
    }

    fn unpin(&self, keys: &[(u64, u32)]) {
        let mut g = self.inner.lock();
        for &k in keys {
            if let Some(c) = g.pins.get_mut(&k) {
                *c -= 1;
                if *c == 0 {
                    g.pins.remove(&k);
                }
            }
        }
    }

    /// Drop every entry **without** spilling (a deliberate drop, not an
    /// eviction — the disk tier keeps whatever was already written;
    /// the dropped entries' pool slots are released as their `Arc`s
    /// die). Lifetime counters and `peak_bytes` survive;
    /// `current_bytes` resets (see the module docs). Outstanding pins
    /// and leases are untouched.
    pub fn clear(&self) {
        let mut g = self.inner.lock();
        g.entries.clear();
        g.stats.current_bytes = 0;
    }

    /// Zero the lifetime counters too (peak collapses to current).
    pub fn reset_stats(&self) {
        self.inner.lock().stats.reset_lifetime();
    }
}

/// Exclusive right (and obligation) to materialize one document.
/// Publish a fresh entry with [`PrefillLease::publish`], or — when the
/// lease carries a [`partial`](Self::partial) entry — refill its
/// missing blocks in place and call
/// [`publish_restored`](Self::publish_restored). Dropping the lease
/// without publishing (prefill error, panic) abandons it so blocked
/// waiters retry instead of hanging.
pub struct PrefillLease {
    host: Arc<HostDocCache>,
    hash: u64,
    done: bool,
    partial: Option<Arc<DocEntry>>,
}

impl PrefillLease {
    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// The partially evicted entry this lease was issued over, if any:
    /// the holder restores its missing blocks (disk, else prefill)
    /// instead of rebuilding the document.
    pub fn partial(&self) -> Option<Arc<DocEntry>> {
        self.partial.clone()
    }

    pub fn publish(mut self, entry: Arc<DocEntry>) {
        self.done = true;
        self.host.finish_lease(self.hash, Some(entry));
    }

    /// Complete a refill: the [`Self::partial`] entry is fully
    /// resident again.
    pub fn publish_restored(mut self) {
        self.done = true;
        self.host.finish_restored(self.hash);
    }
}

impl Drop for PrefillLease {
    fn drop(&mut self) {
        if !self.done {
            self.host.finish_lease(self.hash, None);
        }
    }
}

/// Counted pin registry shared between an [`EngineDocCache`] and the
/// [`PinGuard`]s it hands out (the guard outlives the borrow of the
/// cache, so the registry is refcounted). Residency eviction is
/// doc-granular, so its registry stays keyed by hash.
type PinMap = Arc<Mutex<HashMap<u64, u32>>>;

fn pin_map_remove(map: &PinMap, hashes: &[u64]) {
    let mut m = map.lock();
    for &h in hashes {
        if let Some(c) = m.get_mut(&h) {
            *c -= 1;
            if *c == 0 {
                m.remove(&h);
            }
        }
    }
}

/// RAII pin over a set of `(document, block)` keys. Held by in-flight
/// sessions (and the engine batch loop) over their planned
/// `doc_hashes` — as whole-document [`PIN_ALL`] pins, because dynamic
/// sparse selection may read any block mid-decode — so eviction can
/// never race a live assemble; block-granular guards
/// ([`PinGuard::new_blocks`]) protect individual blocks while the rest
/// of the document stays evictable. The host tier honors every
/// engine's pins (its entries are shared); a residency tier honors
/// only its **own** engine's pins — evicting another engine's resident
/// copy can never invalidate `Arc`-held documents, and must not be
/// blockable cross-engine.
pub struct PinGuard {
    host: Arc<HostDocCache>,
    /// The pinning engine's own residency-tier pin registry.
    local: Option<PinMap>,
    keys: Vec<(u64, u32)>,
}

impl PinGuard {
    /// Pin whole documents (`hashes`, block [`PIN_ALL`]) in `host`
    /// against eviction until the guard drops. Hashes not yet present
    /// are pinned prospectively (a publish racing the pin is still
    /// protected). Reentrant: pins are counted.
    pub fn new(host: Arc<HostDocCache>, hashes: &[u64]) -> PinGuard {
        let keys: Vec<(u64, u32)> =
            hashes.iter().map(|&h| (h, PIN_ALL)).collect();
        Self::new_blocks(host, &keys)
    }

    /// Pin individual `(hash, block)` keys — the rest of each document
    /// stays evictable.
    pub fn new_blocks(host: Arc<HostDocCache>, keys: &[(u64, u32)])
                      -> PinGuard {
        {
            let mut g = host.inner.lock();
            for &k in keys {
                *g.pins.entry(k).or_insert(0) += 1;
            }
        }
        PinGuard { host, local: None, keys: keys.to_vec() }
    }

    /// [`Self::new`] plus a doc-granular pin in the issuing engine's
    /// own registry (see [`EngineDocCache::pin_planned`]).
    fn with_local(host: Arc<HostDocCache>, local: PinMap,
                  hashes: &[u64]) -> PinGuard {
        {
            let mut m = local.lock();
            for &h in hashes {
                *m.entry(h).or_insert(0) += 1;
            }
        }
        let mut guard = PinGuard::new(host, hashes);
        guard.local = Some(local);
        guard
    }

    /// The pinned document hashes (deduplicated against block keys).
    pub fn hashes(&self) -> Vec<u64> {
        let mut hs: Vec<u64> = self.keys.iter().map(|k| k.0).collect();
        hs.dedup();
        hs
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.host.unpin(&self.keys);
        if let Some(local) = &self.local {
            let hashes: Vec<u64> =
                self.keys.iter().map(|k| k.0).collect();
            pin_map_remove(local, &hashes);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-engine residency tier
// ---------------------------------------------------------------------------

/// Where a [`EngineDocCache::get_or_prefill`] found the document.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierHit {
    /// Already device-resident on this engine.
    Resident,
    /// Host-tier hit (published by another engine or an earlier
    /// request); promoted to resident without any prefill.
    Host,
    /// Loaded from the persistent disk tier (spilled by an earlier
    /// eviction or a previous process) and re-published to the host
    /// tier — no model prefill ran. Includes per-block refills of a
    /// partially evicted document served entirely from disk.
    Disk,
    /// Fetched from the owning cluster peer (`--peers` mode): the
    /// serialized entry shipped over the wire, decoded into the pool,
    /// and published to the host tier — no model prefill ran here or
    /// (thanks to the owner's own exactly-once lease) anywhere else.
    Peer,
    /// Cold somewhere: this call ran a prefill (whole document, or the
    /// missing blocks of a partial one) and published the result.
    Prefilled,
}

impl TierHit {
    /// Cache-warm semantics: did the request avoid a fresh prefill?
    pub fn is_warm(self) -> bool {
        self != TierHit::Prefilled
    }
}

struct ResidentSlot {
    entry: Arc<DocEntry>,
    last_use: u64,
}

/// One engine's residency tier over the shared host tier. Not
/// thread-safe by itself — it lives on the engine thread, like the
/// model; all cross-engine sharing happens through the host tier.
pub struct EngineDocCache {
    host: Arc<HostDocCache>,
    resident: HashMap<u64, ResidentSlot>,
    clock: u64,
    budget_bytes: usize,
    policy: Box<dyn EvictionPolicy>,
    stats: CacheStats,
    /// Snapshot at the last [`Self::take_stats_delta`] flush.
    flushed: CacheStats,
    residency: Option<ResidencyHandle>,
    /// This engine's own pins (see [`PinGuard`]): the only pins its
    /// residency eviction honors.
    own_pins: PinMap,
}

impl EngineDocCache {
    pub fn new(host: Arc<HostDocCache>, budget_bytes: usize)
               -> EngineDocCache {
        Self::with_policy(host, budget_bytes, Box::new(LruPolicy))
    }

    pub fn with_policy(host: Arc<HostDocCache>, budget_bytes: usize,
                       policy: Box<dyn EvictionPolicy>) -> EngineDocCache {
        EngineDocCache {
            host,
            resident: HashMap::new(),
            clock: 0,
            budget_bytes,
            policy,
            stats: CacheStats::default(),
            flushed: CacheStats::default(),
            residency: None,
            own_pins: Arc::new(Mutex::named("pin-map", HashMap::new())),
        }
    }

    /// Advertise residency changes on a shared board (router
    /// cache-aware placement).
    pub fn with_residency(mut self, handle: Option<ResidencyHandle>)
                          -> EngineDocCache {
        self.residency = handle;
        self
    }

    /// Self-contained unbounded store (eval harness, examples, tests):
    /// a private unbounded host tier beneath an unbounded residency
    /// tier.
    pub fn unbounded() -> EngineDocCache {
        Self::new(Arc::new(HostDocCache::unbounded()), usize::MAX)
    }

    pub fn host(&self) -> &Arc<HostDocCache> {
        &self.host
    }

    /// The backing KV block pool (the host tier's).
    pub fn pool(&self) -> &Arc<KvBlockPool> {
        self.host.pool()
    }

    /// This engine's residency-tier stats.
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Snapshot of the shared host tier's stats.
    pub fn host_stats(&self) -> CacheStats {
        self.host.stats()
    }

    /// Residency-tier counters accumulated since the previous call
    /// (`current_bytes`/`peak_bytes` are absolute). The engine flushes
    /// these into [`crate::metrics::Metrics`] after every batch.
    pub fn take_stats_delta(&mut self) -> CacheStats {
        let d = CacheStats {
            hits: self.stats.hits.saturating_sub(self.flushed.hits),
            misses: self.stats.misses.saturating_sub(self.flushed.misses),
            evictions: self
                .stats
                .evictions
                .saturating_sub(self.flushed.evictions),
            publishes: self
                .stats
                .publishes
                .saturating_sub(self.flushed.publishes),
            reinserts: self
                .stats
                .reinserts
                .saturating_sub(self.flushed.reinserts),
            hash_collisions: self
                .stats
                .hash_collisions
                .saturating_sub(self.flushed.hash_collisions),
            current_bytes: self.stats.current_bytes,
            peak_bytes: self.stats.peak_bytes,
        };
        self.flushed = self.stats.clone();
        d
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    /// Resident on this engine (the host tier may hold more).
    pub fn contains(&self, tokens: &[i32]) -> bool {
        self.resident.contains_key(&doc_hash(tokens))
    }

    /// Pin the planned hashes for the lifetime of the returned guard:
    /// globally in the host tier (whole documents — see [`PinGuard`]),
    /// and locally for this engine's own residency eviction.
    pub fn pin_planned(&self, hashes: &[u64]) -> PinGuard {
        PinGuard::with_local(Arc::clone(&self.host),
                             Arc::clone(&self.own_pins), hashes)
    }

    /// Block-granular host pins (no residency-tier pin — residency is
    /// doc-granular and its eviction never frees pool slots).
    pub fn pin_planned_blocks(&self, keys: &[(u64, u32)]) -> PinGuard {
        PinGuard::new_blocks(Arc::clone(&self.host), keys)
    }

    /// Resident-tier probe with the collision check: `Some` only when
    /// the stored token ids match the requested document **and** every
    /// KV block is still resident (the host may have partially evicted
    /// the shared entry from under our `Arc`).
    fn resident_hit(&mut self, hash: u64, tokens: &[i32])
                    -> Option<Arc<DocEntry>> {
        let slot = self.resident.get_mut(&hash)?;
        if slot.entry.tokens != tokens {
            self.stats.hash_collisions += 1;
            return None;
        }
        if !slot.entry.kv.is_fully_resident() {
            return None; // refill through the host path
        }
        slot.last_use = self.clock;
        self.stats.hits += 1;
        Some(Arc::clone(&slot.entry))
    }

    /// Fetch the document's KV cache: resident tier, then the shared
    /// host tier, then — under an exactly-once lease — the persistent
    /// disk tier, then prefill (at local positions, offset 0 — the
    /// multiple-context regime), publishing the result back to the
    /// host tier either way. A partially evicted entry is refilled in
    /// place: missing blocks come from disk when possible, else from a
    /// prefill (whose resident blocks are discarded — only the gaps
    /// are installed).
    pub fn get_or_prefill(&mut self, model: &Model, tokens: &[i32])
                          -> Result<(Arc<DocEntry>, TierHit)> {
        let h = doc_hash(tokens);
        self.clock += 1;
        if let Some(entry) = self.resident_hit(h, tokens) {
            return Ok((entry, TierHit::Resident));
        }
        self.stats.misses += 1;
        match HostDocCache::lookup_or_begin(&self.host, h, tokens) {
            HostLookup::Hit(entry) => {
                self.admit(Arc::clone(&entry));
                Ok((entry, TierHit::Host))
            }
            HostLookup::Miss(lease) => {
                // the lease serializes the disk read, the refill, and
                // the prefill: each absent document (or block set) is
                // materialized at most once process-wide, whichever
                // source supplies it
                let disk = self.host.disk().cloned();
                if let Some(partial) = lease.partial() {
                    let mut hit = TierHit::Disk;
                    if let Some(disk) = &disk {
                        disk.load_blocks_into(h, tokens, &partial.kv);
                    }
                    if !partial.kv.is_fully_resident() {
                        let out = model.prefill_doc(tokens, 0)?;
                        partial.kv.install_missing_from(&out.kv)?;
                        hit = TierHit::Prefilled;
                    }
                    lease.publish_restored();
                    self.admit(Arc::clone(&partial));
                    return Ok((partial, hit));
                }
                if let Some(disk) = &disk {
                    if let Some(entry) =
                        disk.load(h, tokens, self.host.pool())
                    {
                        if entry.kv.is_fully_resident() {
                            let entry = Arc::new(entry);
                            lease.publish(Arc::clone(&entry));
                            self.admit(Arc::clone(&entry));
                            return Ok((entry, TierHit::Disk));
                        }
                        // blocks missing on disk (a quarantined
                        // corrupt block): prefill fills the gaps, the
                        // good blocks are kept
                        let out = model.prefill_doc(tokens, 0)?;
                        entry.kv.install_missing_from(&out.kv)?;
                        let entry = Arc::new(entry);
                        lease.publish(Arc::clone(&entry));
                        self.admit(Arc::clone(&entry));
                        return Ok((entry, TierHit::Prefilled));
                    }
                }
                // last warm chance: the owning cluster peer. Any
                // failure (dead peer, timeout, damaged payload,
                // injected fault) decodes to None and degrades to the
                // prefill below — the request never fails on a peer.
                let peers = self.host.peers().cloned();
                if let Some(peers) = &peers {
                    if peers.owner_is_remote(h) {
                        if let Some(entry) = peers
                            .fetch(h, tokens)
                            .and_then(|bytes| {
                                disk_mod::entry_from_bytes(
                                    h, tokens, self.host.pool(), &bytes)
                            })
                        {
                            let entry = Arc::new(entry);
                            lease.publish(Arc::clone(&entry));
                            self.admit(Arc::clone(&entry));
                            return Ok((entry, TierHit::Peer));
                        }
                    }
                }
                // prefill outside any lock; on error the lease drop
                // wakes waiters to retry for themselves
                let out = model.prefill_doc(tokens, 0)?;
                let entry = Arc::new(DocEntry::new(
                    self.host.pool(), tokens.to_vec(), out)?);
                lease.publish(Arc::clone(&entry));
                self.admit(Arc::clone(&entry));
                Ok((entry, TierHit::Prefilled))
            }
        }
    }

    /// Model-free lookup: resident tier, then host tier, then the
    /// persistent disk tier (promoting a hit to resident and — for a
    /// disk hit — re-publishing it to the host tier; a partially
    /// evicted entry is refilled from disk when the blocks are there);
    /// `None` on a true miss (no model, so gaps disk can't fill stay).
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<Arc<DocEntry>> {
        let h = doc_hash(tokens);
        self.clock += 1;
        if let Some(entry) = self.resident_hit(h, tokens) {
            return Some(entry);
        }
        self.stats.misses += 1;
        if let Some(entry) = self.host.try_lookup(h, tokens) {
            self.admit(Arc::clone(&entry));
            return Some(entry);
        }
        let disk = self.host.disk().cloned()?;
        if let Some(partial) = self.host.partial_entry(h, tokens) {
            disk.load_blocks_into(h, tokens, &partial.kv);
            if partial.kv.is_fully_resident() {
                self.host.note_refilled(h);
                self.admit(Arc::clone(&partial));
                return Some(partial);
            }
            return None;
        }
        let entry = disk.load(h, tokens, self.host.pool())?;
        if !entry.kv.is_fully_resident() {
            return None; // partial disk file; needs a prefill path
        }
        let entry = Arc::new(entry);
        self.host.publish(Arc::clone(&entry));
        self.admit(Arc::clone(&entry));
        Some(entry)
    }

    /// Warm the host tier from the persistent disk tier for a set of
    /// planned documents. The engine's admission thread calls this on
    /// a wave's deduplicated doc hashes *while the decode thread keeps
    /// emitting tokens*, so disk load latency overlaps decode compute
    /// the same way assemble does. Documents already fully resident
    /// (engine or host) are skipped; partially evicted host entries
    /// are refilled block-wise; returns how many documents disk
    /// completed. (Prefetch is leaseless — two engines racing on one
    /// hash can at worst duplicate a file read, never a prefill.)
    pub fn prefetch_from_disk(&mut self, docs: &[(u64, &[i32])]) -> usize {
        let Some(disk) = self.host.disk().cloned() else { return 0 };
        let mut loaded = 0;
        for &(hash, tokens) in docs {
            if self
                .resident
                .get(&hash)
                .map_or(false, |s| s.entry.kv.is_fully_resident())
            {
                continue;
            }
            if let Some(partial) = self.host.partial_entry(hash, tokens)
            {
                disk.load_blocks_into(hash, tokens, &partial.kv);
                if partial.kv.is_fully_resident() {
                    self.host.note_refilled(hash);
                    self.admit(Arc::clone(&partial));
                    loaded += 1;
                }
                continue;
            }
            if self.host.contains(hash) {
                continue; // fully resident (or a collision — the
                          // prefill path sorts that out)
            }
            if let Some(entry) = disk.load(hash, tokens,
                                           self.host.pool()) {
                if entry.kv.is_fully_resident() {
                    let entry = Arc::new(entry);
                    self.host.publish(Arc::clone(&entry));
                    self.admit(entry);
                    loaded += 1;
                }
            }
        }
        loaded
    }

    /// Insert a pre-computed entry (tests / replay): published to the
    /// host tier and admitted as resident here.
    // allow: test/replay-only entry point, never on a request path; a
    // malformed KV shape is a caller bug worth failing loudly at the
    // call site. Tracked in rust/lint_allowlist.txt.
    #[allow(clippy::expect_used)]
    pub fn insert(&mut self, tokens: Vec<i32>, out: PrefillDocOut) {
        let entry = DocEntry::new(self.host.pool(), tokens, out)
            .expect("prefill output must have a [L,2,H,T,Dh] KV");
        self.insert_entry(Arc::new(entry));
    }

    /// [`Self::insert`] over an already-built entry (disk replay,
    /// forged-collision tests).
    pub fn insert_entry(&mut self, entry: Arc<DocEntry>) {
        self.host.publish(Arc::clone(&entry));
        self.admit(entry);
    }

    /// Make an entry device-resident, with the duplicate-insert byte
    /// accounting fix: replacing an existing hash subtracts the old
    /// entry's bytes first.
    fn admit(&mut self, entry: Arc<DocEntry>) {
        let (h, bytes) = (entry.hash, entry.bytes);
        self.clock += 1;
        let replaced = self
            .resident
            .insert(h, ResidentSlot { entry, last_use: self.clock })
            .map(|old| old.entry.bytes);
        if replaced.is_none() {
            if let Some(r) = &self.residency {
                r.insert(h);
            }
        }
        self.stats.note_insert(bytes, replaced);
        self.evict_to_budget();
    }

    /// Residency eviction stays **doc-granular**: the tier holds
    /// `Arc`s into pooled entries (no private copies), so dropping a
    /// resident ref frees no pool slots — block granularity lives in
    /// the host tier, which owns the bytes.
    fn evict_to_budget(&mut self) {
        if self.stats.current_bytes <= self.budget_bytes {
            return;
        }
        // only this engine's own pins matter here: evicting our
        // resident copy never invalidates Arc-held docs, and another
        // engine's session must not be able to wedge us over our
        // device budget. One snapshot for the whole pass.
        let pinned: HashSet<u64> =
            self.own_pins.lock().keys().copied().collect();
        let mut candidates: Vec<EvictionCandidate> = self
            .resident
            .iter()
            .filter(|e| !pinned.contains(e.0))
            .map(|(&h, s)| EvictionCandidate {
                hash: h,
                block: WHOLE_ENTRY,
                bytes: s.entry.bytes,
                last_use: s.last_use,
                recompute_cost: s.entry.tokens.len(),
            })
            .collect();
        while self.stats.current_bytes > self.budget_bytes
            && self.resident.len() > 1
        {
            let Some(i) = self.policy.pick_victim(&candidates) else {
                break;
            };
            let victim = candidates.swap_remove(i).hash;
            let Some(slot) = self.resident.remove(&victim) else { break };
            self.stats.current_bytes -= slot.entry.bytes;
            self.stats.evictions += 1;
            if let Some(r) = &self.residency {
                r.remove(victim);
            }
        }
    }

    /// Drop this engine's residency (the host tier keeps its entries).
    /// Lifetime counters and `peak_bytes` survive; `current_bytes`
    /// resets (see the module docs).
    pub fn clear(&mut self) {
        if let Some(r) = &self.residency {
            r.clear();
        }
        self.resident.clear();
        self.stats.current_bytes = 0;
    }

    /// Drop residency **and** the backing host tier's entries (eval
    /// harness memory bound between disjoint sample sets).
    pub fn clear_all(&mut self) {
        self.clear();
        self.host.clear();
    }

    /// Zero the lifetime counters too (peak collapses to current).
    pub fn reset_stats(&mut self) {
        self.stats.reset_lifetime();
        self.flushed = self.stats.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PrefillDocOut;

    fn fake_entry(bytes_hint: usize) -> PrefillDocOut {
        // bytes = (kv + attn + q_local) * 4; use kv only for sizing
        PrefillDocOut {
            kv: Tensor::zeros(&[1, 2, 1, bytes_hint / 8, 1]),
            attn: Tensor::zeros(&[1, 1, 1, 1]),
            q_local: Tensor::zeros(&[1, 1, 1]),
        }
    }

    fn arc_entry(pool: &Arc<KvBlockPool>, tokens: Vec<i32>,
                 bytes_hint: usize) -> Arc<DocEntry> {
        Arc::new(DocEntry::new(pool, tokens, fake_entry(bytes_hint))
            .unwrap())
    }

    #[test]
    fn hash_is_content_based() {
        assert_eq!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 3]));
        assert_ne!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 4]));
        assert_ne!(doc_hash(&[1, 2]), doc_hash(&[2, 1]));
    }

    #[test]
    fn doc_hash_is_fnv64_over_le_bytes() {
        // the streamed doc hash and the byte-level fnv64 (disk-tier
        // checksum) must stay bit-identical
        let tokens = [7i32, -3, 65_536];
        let bytes: Vec<u8> =
            tokens.iter().flat_map(|t| t.to_le_bytes()).collect();
        assert_eq!(doc_hash(&tokens), fnv64(&bytes));
        assert_eq!(doc_hash(&[]), fnv64(&[]));
    }

    #[test]
    fn insert_and_contains() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1, 2, 3], fake_entry(64));
        assert!(s.contains(&[1, 2, 3]));
        assert!(!s.contains(&[9, 9]));
        assert_eq!(s.len(), 1);
        assert_eq!(s.host().len(), 1);
        assert!(s.stats().current_bytes > 0);
        assert_eq!(s.host_stats().current_bytes,
                   s.stats().current_bytes);
        // the entry's KV landed in the shared pool
        assert!(s.pool().stats().slots_live > 0);
    }

    #[test]
    fn duplicate_insert_does_not_leak_bytes() {
        // the seed bug: re-inserting an existing hash inflated
        // current_bytes forever; both tiers must subtract the old entry
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1, 2], fake_entry(128));
        let once = s.stats().current_bytes;
        s.insert(vec![1, 2], fake_entry(128));
        assert_eq!(s.stats().current_bytes, once,
                   "residency tier leaked duplicate-insert bytes");
        assert_eq!(s.stats().reinserts, 1);
        assert_eq!(s.host_stats().current_bytes, once,
                   "host tier leaked duplicate-insert bytes");
        assert_eq!(s.host_stats().reinserts, 1);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each entry: kv 32 elems (128B) + attn 4B + q_local 4B = 136B
        let host = Arc::new(HostDocCache::unbounded());
        let mut s = EngineDocCache::new(Arc::clone(&host), 300);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        assert_eq!(s.len(), 2);
        s.insert(vec![3], fake_entry(128));
        assert!(s.stats().evictions >= 1);
        assert!(s.stats().current_bytes <= 300);
        // entry 1 was the LRU victim — resident no longer, but the
        // unbounded host tier still holds it (tiering, not loss)
        assert!(!s.contains(&[1]));
        assert!(s.contains(&[3]));
        assert!(host.contains(doc_hash(&[1])));
        assert!(s.lookup(&[1]).is_some(), "host tier must backfill");
    }

    #[test]
    fn host_eviction_skips_pinned_entries() {
        let host = Arc::new(HostDocCache::new(300));
        let e1 = arc_entry(host.pool(), vec![1], 128);
        let pin = PinGuard::new(Arc::clone(&host), &[e1.hash]);
        host.publish(e1);
        host.publish(arc_entry(host.pool(), vec![2], 128));
        host.publish(arc_entry(host.pool(), vec![3], 128)); // over budget
        assert!(host.stats().evictions >= 1);
        assert!(host.contains(doc_hash(&[1])),
                "pinned entry was evicted");
        assert!(!host.contains(doc_hash(&[2])),
                "LRU unpinned entry should have been the victim");
        drop(pin);
        assert!(!host.is_pinned(doc_hash(&[1])));
        host.publish(arc_entry(host.pool(), vec![4], 128));
        assert!(!host.contains(doc_hash(&[1])),
                "unpinned entry must become evictable");
    }

    #[test]
    fn pinned_head_blocks_survive_while_tail_evicts() {
        // 2-token pool blocks; fake_entry(48) has a 6-token KV -> 3
        // blocks of 16B each (pte 2), entry total 56B (48 + 4 + 4)
        let host =
            Arc::new(HostDocCache::new(100).with_block_tokens(2));
        let e1 = arc_entry(host.pool(), vec![1, 2, 3], 48);
        let h1 = e1.hash;
        // pin only the head block: the tail must stay evictable
        let pin = PinGuard::new_blocks(Arc::clone(&host), &[(h1, 0)]);
        host.publish(Arc::clone(&e1));
        host.publish(arc_entry(host.pool(), vec![4, 5, 6], 48));
        // 112B > 100B: exactly one 16B block must go — doc 1 is LRU,
        // its block 0 is pinned, so the cold tail (block 2) leaves
        assert!(host.contains(h1),
                "partially evicted doc must stay in the tier");
        assert!(!e1.kv.is_fully_resident(),
                "the victim doc must lose a block");
        assert_eq!(e1.kv.resident_block_indexes(), vec![0, 1],
                   "pinned head survives; cold tail evicts first");
        assert_eq!(host.stats().evictions, 0,
                   "block eviction must not count a whole-entry \
                    eviction");
        let ps = host.pool().stats();
        assert_eq!(ps.blocks_evicted, 1);
        assert_eq!(ps.partial_evictions, 1);
        // resident blocks still serve reads (the partial-warm-hit
        // contract); the evicted one errors
        let mut span = vec![0f32; 2];
        assert!(e1.kv.copy_span(0, 0, 0, 0, 2, &mut span).is_ok());
        assert!(e1.kv.copy_span(0, 0, 0, 4, 2, &mut span).is_err());
        drop(pin);
        // with the pin gone and more pressure, doc 1 drains fully and
        // is removed whole
        host.publish(arc_entry(host.pool(), vec![7, 8, 9], 48));
        assert!(!host.contains(h1),
                "unpinned doc must drain head blocks too");
        assert!(host.stats().evictions >= 1);
    }

    #[test]
    fn resident_eviction_skips_own_pinned_entries() {
        let host = Arc::new(HostDocCache::unbounded());
        let mut s = EngineDocCache::new(Arc::clone(&host), 300);
        let pinned_hash = doc_hash(&[1]);
        let _pin = s.pin_planned(&[pinned_hash]);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        s.insert(vec![3], fake_entry(128));
        assert!(s.contains(&[1]), "pinned entry evicted from residency");
        assert!(!s.contains(&[2]));
    }

    #[test]
    fn resident_eviction_ignores_other_engines_pins() {
        // engine A's session pins must not wedge engine B over its
        // device budget: B may evict its own copy (A's Arc-held docs
        // and the host entry are untouched)
        let host = Arc::new(HostDocCache::unbounded());
        let a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let mut b = EngineDocCache::new(Arc::clone(&host), 300);
        let _pin = a.pin_planned(&[doc_hash(&[1])]);
        b.insert(vec![1], fake_entry(128));
        b.insert(vec![2], fake_entry(128));
        b.insert(vec![3], fake_entry(128));
        assert!(b.stats().current_bytes <= 300,
                "cross-engine pin wedged B over its budget");
        assert!(!b.contains(&[1]), "B's own LRU copy must be evictable");
        assert!(host.contains(doc_hash(&[1])),
                "the shared host entry honors A's pin");
        assert!(host.is_pinned(doc_hash(&[1])));
    }

    #[test]
    fn cross_engine_host_tier_hit() {
        // engine B hits what engine A published, without any prefill
        let host = Arc::new(HostDocCache::unbounded());
        let mut a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let mut b = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        a.insert(vec![7, 8], fake_entry(64));
        assert!(!b.contains(&[7, 8]));
        let hit = b.lookup(&[7, 8]).expect("host tier hit");
        assert_eq!(hit.hash, doc_hash(&[7, 8]));
        assert!(b.contains(&[7, 8]), "host hit promotes to resident");
        assert_eq!(host.stats().hits, 1);
        assert_eq!(b.stats().misses, 1); // residency miss, host hit
        assert!(b.lookup(&[9]).is_none());
    }

    #[test]
    fn identical_docs_share_pool_slots() {
        // two distinct documents with byte-identical KV (all zeros
        // here, as real shared prefixes would be) share pool slots
        let host = Arc::new(HostDocCache::unbounded());
        host.publish(arc_entry(host.pool(), vec![1], 128));
        let live_one = host.pool().stats().slots_live;
        host.publish(arc_entry(host.pool(), vec![2], 128));
        let s = host.pool().stats();
        assert_eq!(s.slots_live, live_one,
                   "identical KV content must share slots");
        assert!(s.share_hits >= 1);
    }

    #[test]
    fn lease_lifecycle_is_exactly_once() {
        let host = Arc::new(HostDocCache::unbounded());
        let h = doc_hash(&[5]);
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[5])
        else {
            panic!("expected miss");
        };
        assert_eq!(lease.hash(), h);
        assert!(lease.partial().is_none());
        lease.publish(arc_entry(host.pool(), vec![5], 64));
        match HostDocCache::lookup_or_begin(&host, h, &[5]) {
            HostLookup::Hit(e) => assert_eq!(e.hash, h),
            HostLookup::Miss(_) => panic!("published entry must hit"),
        }
        assert_eq!(host.stats().publishes, 1);
        // abandoned lease (failed prefill) re-opens the hash
        let h2 = doc_hash(&[6]);
        let HostLookup::Miss(lease2) =
            HostDocCache::lookup_or_begin(&host, h2, &[6])
        else {
            panic!("expected miss");
        };
        drop(lease2);
        assert!(matches!(
            HostDocCache::lookup_or_begin(&host, h2, &[6]),
            HostLookup::Miss(_)
        ));
    }

    #[test]
    fn concurrent_leases_block_until_publish() {
        let host = Arc::new(HostDocCache::unbounded());
        let h = doc_hash(&[42]);
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[42])
        else {
            panic!("expected miss");
        };
        let waiter = {
            let host = Arc::clone(&host);
            std::thread::spawn(move || {
                match HostDocCache::lookup_or_begin(&host, h, &[42]) {
                    HostLookup::Hit(e) => e.hash,
                    HostLookup::Miss(_) => panic!("waiter must see the \
                                                   publish, not prefill"),
                }
            })
        };
        // give the waiter time to block on the in-flight lease
        std::thread::sleep(std::time::Duration::from_millis(20));
        lease.publish(arc_entry(host.pool(), vec![42], 64));
        assert_eq!(waiter.join().unwrap(), h);
        assert_eq!(host.stats().publishes, 1);
        assert_eq!(host.stats().hits, 1);
    }

    #[test]
    fn partial_entry_leases_carry_the_entry() {
        // a partially evicted entry must read as a refill lease, not a
        // hit and not a fresh-prefill miss
        let host =
            Arc::new(HostDocCache::new(100).with_block_tokens(2));
        host.publish(arc_entry(host.pool(), vec![1, 2, 3], 48));
        host.publish(arc_entry(host.pool(), vec![4, 5, 6], 48));
        let h1 = doc_hash(&[1, 2, 3]);
        // doc 1 lost its tail block to the budget
        assert!(host.partial_entry(h1, &[1, 2, 3]).is_some());
        assert!(host.try_lookup(h1, &[1, 2, 3]).is_none(),
                "a partial entry must not serve a whole-doc hit");
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h1, &[1, 2, 3])
        else {
            panic!("partial entry must lease a refill");
        };
        let partial = lease.partial().expect("lease carries the entry");
        assert_eq!(partial.hash, h1);
        // restore the missing block in place and publish the refill
        for b in partial.kv.missing_block_indexes() {
            let zeros =
                vec![0f32;
                     partial.kv.block_bytes(b as usize) / 4];
            partial.kv.restore_block(b as usize, &zeros).unwrap();
        }
        lease.publish_restored();
        assert!(host.try_lookup(h1, &[1, 2, 3]).is_some(),
                "refilled entry must serve hits again");
        assert_eq!(host.stats().reinserts, 1,
                   "a refill counts as a reinsert, not a publish");
        assert_eq!(host.stats().publishes, 2);
    }

    #[test]
    fn clear_keeps_lifetime_counters() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(128));
        let _ = s.lookup(&[1]);
        let _ = s.lookup(&[9]); // miss
        s.clear_all();
        assert_eq!(s.stats().current_bytes, 0);
        assert_eq!(s.host_stats().current_bytes, 0);
        assert_eq!(s.len(), 0);
        // dropping the entries released their pool slots
        assert_eq!(s.pool().stats().slots_live, 0);
        // lifetime counters survive clear...
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.stats().misses, 1);
        assert_eq!(s.stats().publishes, 1);
        assert!(s.stats().peak_bytes > 0);
        // ...and reset_stats zeroes them
        s.reset_stats();
        s.host().reset_stats();
        assert_eq!(*s.stats(), CacheStats::default());
        assert_eq!(s.host_stats(), CacheStats::default());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(128));
        let p1 = s.stats().peak_bytes;
        s.insert(vec![2], fake_entry(128));
        assert!(s.stats().peak_bytes > p1);
        s.clear();
        assert_eq!(s.stats().current_bytes, 0);
        assert!(s.stats().peak_bytes > p1);
    }

    #[test]
    fn stats_delta_accumulates_between_flushes() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(64));
        let _ = s.lookup(&[1]);
        let d1 = s.take_stats_delta();
        assert_eq!((d1.hits, d1.publishes), (1, 1));
        let d2 = s.take_stats_delta();
        assert_eq!((d2.hits, d2.publishes), (0, 0));
        assert_eq!(d2.current_bytes, s.stats().current_bytes);
        let _ = s.lookup(&[1]);
        assert_eq!(s.take_stats_delta().hits, 1);
    }

    #[test]
    fn auto_sized_budget_raised_by_engines_only() {
        let auto = HostDocCache::auto_sized(Box::new(LruPolicy));
        assert_eq!(auto.budget_bytes(), 0);
        auto.ensure_min_budget(1024);
        auto.ensure_min_budget(512); // never lowers
        assert_eq!(auto.budget_bytes(), 1024);
        // an explicit budget is the operator's word: ensure_min is a
        // no-op
        let fixed = HostDocCache::new(300);
        fixed.ensure_min_budget(1 << 30);
        assert_eq!(fixed.budget_bytes(), 300);
    }

    #[test]
    fn tier_hit_warmth() {
        assert!(TierHit::Resident.is_warm());
        assert!(TierHit::Host.is_warm());
        assert!(TierHit::Disk.is_warm());
        assert!(TierHit::Peer.is_warm());
        assert!(!TierHit::Prefilled.is_warm());
    }

    /// An entry whose `hash` field deliberately disagrees with its
    /// token content — two documents colliding on one content hash.
    fn forged(pool: &Arc<KvBlockPool>, hash: u64, tokens: Vec<i32>)
              -> Arc<DocEntry> {
        let e = DocEntry::new(pool, tokens, fake_entry(64)).unwrap();
        Arc::new(DocEntry { hash, ..e })
    }

    #[test]
    fn host_collision_is_a_miss_not_a_wrong_hit() {
        // the hash of the document we will ask for, occupied by a
        // *different* document's entry
        let h = doc_hash(&[1, 2, 3]);
        let host = Arc::new(HostDocCache::unbounded());
        host.publish(forged(host.pool(), h, vec![9, 9]));
        assert!(host.try_lookup(h, &[1, 2, 3]).is_none(),
                "collision served another document's KV");
        let s = host.stats();
        assert_eq!(s.hash_collisions, 1);
        assert_eq!(s.misses, 1);
        // the stored document itself still hits
        assert!(host.try_lookup(h, &[9, 9]).is_some());
        // the leasing path also treats the collision as a miss, and
        // its publish replaces the colliding entry (reinsert, no leak)
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[1, 2, 3])
        else {
            panic!("collision must fall through to a lease");
        };
        assert!(lease.partial().is_none(),
                "a collision is not a partial refill");
        lease.publish(forged(host.pool(), h, vec![1, 2, 3]));
        assert!(host.try_lookup(h, &[1, 2, 3]).is_some());
        assert_eq!(host.stats().reinserts, 1);
        assert_eq!(host.len(), 1);
    }

    #[test]
    fn resident_collision_is_a_miss_not_a_wrong_hit() {
        let h = doc_hash(&[1, 2, 3]);
        let mut s = EngineDocCache::unbounded();
        let e = forged(s.pool(), h, vec![9, 9]);
        s.insert_entry(e);
        // both the resident slot and the host entry hold [9,9] under
        // the hash of [1,2,3]: the lookup must come back empty
        assert!(s.lookup(&[1, 2, 3]).is_none(),
                "collision served another document's KV");
        assert_eq!(s.stats().hash_collisions, 1);
        assert_eq!(s.host_stats().hash_collisions, 1);
    }

    fn disk_fixture(tag: &str) -> (std::path::PathBuf, Arc<DiskDocCache>) {
        let dir = std::env::temp_dir().join(format!(
            "samkv-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let disk = Arc::new(DiskDocCache::open(&dir, usize::MAX).unwrap());
        (dir, disk)
    }

    #[test]
    fn host_eviction_spills_to_disk_and_reloads() {
        let (dir, disk) = disk_fixture("spill");
        // each entry is 136B; a 300B host budget evicts the LRU on the
        // third publish — the victim must land on disk, not vanish
        let host = Arc::new(HostDocCache::new(300)
            .with_disk(Arc::clone(&disk), DiskWriteback::Evict));
        let mut a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        a.insert(vec![1], fake_entry(128));
        a.insert(vec![2], fake_entry(128));
        a.insert(vec![3], fake_entry(128));
        assert!(host.stats().evictions >= 1);
        assert!(!host.contains(doc_hash(&[1])));
        assert!(disk.contains(doc_hash(&[1])),
                "evicted entry must spill to the disk tier");
        assert_eq!(disk.stats().spills, 1,
                   "evict mode only writes victims");
        assert!(host.pool().stats().blocks_spilled >= 1);
        // a cold engine re-loads the spilled entry through the tiers
        let mut b = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let e = b.lookup(&[1]).expect("disk tier must backfill");
        assert_eq!(e.tokens, vec![1]);
        assert!(e.kv.is_fully_resident());
        assert_eq!(disk.stats().hits, 1);
        assert!(host.contains(doc_hash(&[1])),
                "disk hit must re-publish to the host tier");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partially_evicted_doc_refills_from_disk() {
        let (dir, disk) = disk_fixture("partial");
        // 2-token blocks; 56B entries over a 100B budget: publishing
        // doc 2 spills exactly one tail block of doc 1 to disk
        let host = Arc::new(HostDocCache::new(100)
            .with_block_tokens(2)
            .with_disk(Arc::clone(&disk), DiskWriteback::Evict));
        let mut s = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        s.insert(vec![1, 2, 3], fake_entry(48));
        s.insert(vec![4, 5, 6], fake_entry(48));
        let h1 = doc_hash(&[1, 2, 3]);
        assert!(host.contains(h1), "doc 1 must only lose a block");
        assert!(host.partial_entry(h1, &[1, 2, 3]).is_some());
        assert_eq!(disk.stats().spills, 1,
                   "the evicted block must spill as a partial file");
        let ps = host.pool().stats();
        assert_eq!((ps.blocks_evicted, ps.blocks_spilled,
                    ps.partial_evictions), (1, 1, 1));
        // a lookup refills the missing block from the partial disk
        // file — no prefill, bytes re-accounted, entry whole again
        let e = s.lookup(&[1, 2, 3]).expect("block refill from disk");
        assert!(e.kv.is_fully_resident());
        assert!(host.try_lookup(h1, &[1, 2, 3]).is_some());
        assert_eq!(host.stats().reinserts, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_through_persists_on_publish() {
        let (dir, disk) = disk_fixture("through");
        let host = Arc::new(HostDocCache::unbounded()
            .with_disk(Arc::clone(&disk), DiskWriteback::Through));
        assert_eq!(host.disk_writeback(), Some(DiskWriteback::Through));
        host.publish(arc_entry(host.pool(), vec![4], 128));
        assert!(disk.contains(doc_hash(&[4])),
                "write-through must persist the insert immediately");
        assert_eq!(disk.stats().spills, 1);
        // re-publishing the same content is one write total
        host.publish(arc_entry(host.pool(), vec![4], 128));
        assert_eq!(disk.stats().spills, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writeback_off_never_writes_but_still_reads() {
        let (dir, disk) = disk_fixture("off");
        // pre-seed the directory as if by an earlier process
        let seed_pool = Arc::new(KvBlockPool::new(64));
        disk.store(&DocEntry::new(&seed_pool, vec![8, 8],
                                  fake_entry(64)).unwrap())
            .unwrap();
        let host = Arc::new(HostDocCache::new(300)
            .with_disk(Arc::clone(&disk), DiskWriteback::Off));
        let mut s = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        s.insert(vec![3], fake_entry(128)); // host evicts, no spill
        assert_eq!(disk.stats().spills, 1, "off mode must never write");
        // ...but the pre-seeded entry is still readable
        assert!(s.lookup(&[8, 8]).is_some());
        assert_eq!(disk.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn int8_budget_charges_physical_bytes_and_admits_more() {
        // satellite bugfix: the budget must charge encoded (physical)
        // bytes, not logical f32 bytes. fake_entry(1024) is a
        // 128-token KV = 2 default pool blocks of 128 elems each:
        // 512B/block under f32, 132B/block (scale + 1B/elem) under
        // int8 with a zero hot watermark.
        use super::super::codec::codec_for;
        use crate::config::KvCodecKind;
        let budget = 2100; // fits exactly two complete f32 entries
        let f32_host = Arc::new(HostDocCache::new(budget));
        let int8_host = Arc::new(HostDocCache::new(budget)
            .with_codec(codec_for(KvCodecKind::Int8), 0));
        // the encoded entry itself is >= 3.5x smaller than f32
        let probe32 = arc_entry(f32_host.pool(), vec![99], 1024);
        let probe8 = arc_entry(int8_host.pool(), vec![99], 1024);
        assert!(probe32.kv.resident_bytes() as f64
                    >= probe8.kv.resident_bytes() as f64 * 3.5,
                "int8 resident blocks must be >= 3.5x smaller \
                 ({} vs {})", probe32.kv.resident_bytes(),
                probe8.kv.resident_bytes());
        assert!(probe8.bytes < probe32.bytes / 3);
        let mut h32 = Vec::new();
        let mut h8 = Vec::new();
        for i in 0..8 {
            let e = arc_entry(f32_host.pool(), vec![i], 1024);
            f32_host.publish(Arc::clone(&e));
            h32.push(e);
            let e = arc_entry(int8_host.pool(), vec![i], 1024);
            int8_host.publish(Arc::clone(&e));
            h8.push(e);
        }
        assert!(f32_host.stats().current_bytes <= budget);
        assert!(int8_host.stats().current_bytes <= budget);
        // same budget, ~3.9x smaller blocks: the int8 tier keeps >=
        // 3.5x as many KV blocks resident
        let blocks = |hs: &[Arc<DocEntry>]| -> usize {
            hs.iter()
                .map(|e| e.kv.resident_block_indexes().len())
                .sum()
        };
        let (b32, b8) = (blocks(&h32), blocks(&h8));
        assert!(b8 as f64 >= b32 as f64 * 3.5,
                "int8 must admit ~4x more blocks under the same \
                 budget (f32 {b32}, int8 {b8})");
        assert!(int8_host.len() > f32_host.len() * 2);
    }

    #[test]
    fn reset_stats_resets_flush_baseline() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(64));
        let _ = s.lookup(&[1]);
        assert_eq!(s.take_stats_delta().hits, 1);
        // regression: a reset between two flushes must reset the flush
        // baseline too — a baseline above the live counters would make
        // every later delta saturate to zero
        s.reset_stats();
        let _ = s.lookup(&[1]);
        let _ = s.lookup(&[1]);
        let d = s.take_stats_delta();
        assert_eq!(d.hits, 2,
                   "post-reset hits swallowed by a stale flush baseline");
        assert_eq!(s.take_stats_delta().hits, 0);
    }
}
