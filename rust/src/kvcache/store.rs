//! The RAM document-cache tiers: shared host tier + per-engine
//! residency tier (see the [`super`] module docs for the full diagram
//! and the pin-guard contract; the persistent tier beneath them is
//! [`super::disk`]).
//!
//! [`HostDocCache`] is the process-wide, thread-safe, content-addressed
//! tier: one entry per unique document (FNV-1a over token ids), shared
//! by every engine behind an `Arc`. A miss hands the caller a
//! [`PrefillLease`] so each unique document is prefilled **exactly once
//! process-wide** — concurrent engines asking for the same in-flight
//! document block until the lease publishes (or is abandoned on error).
//! With a [`DiskDocCache`] attached ([`HostDocCache::with_disk`]), the
//! lease holder consults the disk tier before paying a model prefill,
//! and host-tier entries are spilled to disk instead of dropped
//! (writeback mode per [`DiskWriteback`]).
//!
//! [`EngineDocCache`] is one engine's residency tier: the subset of
//! host entries "device-resident" for that engine (its own byte budget
//! and LRU clock), consulted first; misses fall through to the host
//! tier, and fresh prefills are published back so one engine's work is
//! every engine's hit.
//!
//! # Hash-collision safety
//!
//! Every tier keys on the FNV-1a content hash, so every by-hash hit
//! **verifies the stored token ids against the requested document**
//! before serving: a mismatch (two documents colliding on one hash) is
//! counted in [`CacheStats::hash_collisions`] and treated as a miss —
//! the colliding prefill then *replaces* the stored entry (reinsert
//! accounting) rather than silently serving another document's KV.
//!
//! # Stats counters: lifetime vs. current
//!
//! [`CacheStats`] mixes two kinds of counters. **Lifetime** counters
//! only grow and survive [`clear`](EngineDocCache::clear): `hits`,
//! `misses`, `evictions`, `publishes`, `reinserts`,
//! `hash_collisions`, and `peak_bytes`
//! (the high-water mark). **Current** state — `current_bytes` — tracks
//! what the tier holds right now and resets to zero on `clear`.
//! [`EngineDocCache::reset_stats`] / [`HostDocCache::reset_stats`]
//! zero the lifetime counters too (peak collapses to the current
//! footprint).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::config::DiskWriteback;
use crate::model::{Model, PrefillDocOut};
use crate::tensor::Tensor;

use super::disk::DiskDocCache;
use super::evict::{EvictionCandidate, EvictionPolicy, LruPolicy};
use super::residency::ResidencyHandle;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over raw bytes — one definition shared by the content hash
/// below and the disk tier's file checksum, so the two can never
/// drift apart.
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over token ids (little-endian bytes) — the document cache
/// key. Streams per token instead of materializing a byte buffer, but
/// is bit-identical to [`fnv64`] over the concatenated `to_le_bytes`.
pub fn doc_hash(tokens: &[i32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// A cached document: prefill outputs + bookkeeping. Shared across
/// engine threads (and with in-flight sessions) via `Arc`, so eviction
/// from either tier never invalidates a live assemble.
#[derive(Debug)]
pub struct DocEntry {
    pub hash: u64,
    pub tokens: Vec<i32>,
    /// `[L, 2, H, Ld, Dh]`, local (position 0-based) RoPE.
    pub kv: Tensor,
    /// `[L, H, Ld, Ld]` attention probabilities.
    pub attn: Tensor,
    /// `[L, H, Dh]` local-window mean Q (Eq. 1 bias source).
    pub q_local: Tensor,
    pub bytes: usize,
}

impl DocEntry {
    fn new(tokens: Vec<i32>, out: PrefillDocOut) -> DocEntry {
        let bytes = out.kv.size_bytes() + out.attn.size_bytes()
            + out.q_local.size_bytes();
        DocEntry {
            hash: doc_hash(&tokens),
            tokens,
            kv: out.kv,
            attn: out.attn,
            q_local: out.q_local,
            bytes,
        }
    }
}

/// Per-tier counters. Lifetime counters (`hits`, `misses`,
/// `evictions`, `publishes`, `reinserts`, `hash_collisions`,
/// `peak_bytes`) survive `clear`; `current_bytes` is current state and
/// resets with the entries (see the module docs).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries inserted: host tier — published prefills; residency
    /// tier — admissions (fresh prefills and host-tier promotions).
    pub publishes: u64,
    /// Inserts that replaced an entry already present under the same
    /// hash (the old entry's bytes are subtracted, never leaked).
    pub reinserts: u64,
    /// By-hash hits whose stored token ids did not match the requested
    /// document (content-hash collision) — served as misses, never as
    /// another document's KV (see the module docs).
    pub hash_collisions: u64,
    pub current_bytes: usize,
    pub peak_bytes: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    fn note_insert(&mut self, bytes: usize, replaced: Option<usize>) {
        if let Some(old) = replaced {
            self.current_bytes -= old;
            self.reinserts += 1;
        }
        self.current_bytes += bytes;
        self.publishes += 1;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    fn reset_lifetime(&mut self) {
        let current = self.current_bytes;
        *self = CacheStats { current_bytes: current,
                             peak_bytes: current,
                             ..CacheStats::default() };
    }
}

// ---------------------------------------------------------------------------
// Host tier
// ---------------------------------------------------------------------------

struct HostSlot {
    entry: Arc<DocEntry>,
    last_use: u64,
}

struct HostInner {
    entries: HashMap<u64, HostSlot>,
    /// Hashes currently being prefilled under a [`PrefillLease`].
    in_flight: HashSet<u64>,
    /// Pin counts per hash (a hash may be pinned before it exists).
    pins: HashMap<u64, u32>,
    clock: u64,
    budget_bytes: usize,
    /// True when the budget was fixed by the operator/caller;
    /// auto-sized tiers let engines raise it from model geometry.
    budget_explicit: bool,
    stats: CacheStats,
}

/// Result of [`HostDocCache::lookup_or_begin`].
pub enum HostLookup {
    /// The entry is cached; use it.
    Hit(Arc<DocEntry>),
    /// Nobody holds this document: the caller must prefill it and
    /// [`PrefillLease::publish`] the result (dropping the lease
    /// without publishing abandons it, waking any waiters to retry).
    Miss(PrefillLease),
}

/// The shared host tier: thread-safe, content-addressed document cache
/// with a byte budget, pluggable eviction, pin guards, exactly-once
/// prefill leasing, and an optional persistent [`DiskDocCache`] tier
/// beneath it (spill on eviction / write-through per
/// [`DiskWriteback`]).
pub struct HostDocCache {
    inner: Mutex<HostInner>,
    published: Condvar,
    policy: Box<dyn EvictionPolicy>,
    disk: Option<DiskTier>,
}

struct DiskTier {
    cache: Arc<DiskDocCache>,
    writeback: DiskWriteback,
}

impl HostDocCache {
    pub fn new(budget_bytes: usize) -> HostDocCache {
        Self::with_policy(budget_bytes, Box::new(LruPolicy))
    }

    pub fn with_policy(budget_bytes: usize,
                       policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        Self::build(budget_bytes, true, policy)
    }

    /// Auto-sized tier: starts with a zero budget that engines raise
    /// via [`Self::ensure_min_budget`] once their model geometry is
    /// known — bounded by default without the caller having to guess
    /// KV sizes up front.
    pub fn auto_sized(policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        Self::build(0, false, policy)
    }

    fn build(budget_bytes: usize, budget_explicit: bool,
             policy: Box<dyn EvictionPolicy>) -> HostDocCache {
        HostDocCache {
            inner: Mutex::new(HostInner {
                entries: HashMap::new(),
                in_flight: HashSet::new(),
                pins: HashMap::new(),
                clock: 0,
                budget_bytes,
                budget_explicit,
                stats: CacheStats::default(),
            }),
            published: Condvar::new(),
            policy,
            disk: None,
        }
    }

    /// Attach the persistent disk tier. Reads always consult it on a
    /// host miss (under the miss's prefill lease, so each absent
    /// document is loaded from disk at most once process-wide);
    /// `writeback` controls when entries are written (spill on
    /// eviction, write-through on insert, or never).
    pub fn with_disk(mut self, disk: Arc<DiskDocCache>,
                     writeback: DiskWriteback) -> HostDocCache {
        self.disk = Some(DiskTier { cache: disk, writeback });
        self
    }

    /// The attached persistent tier, if any.
    pub fn disk(&self) -> Option<&Arc<DiskDocCache>> {
        self.disk.as_ref().map(|d| &d.cache)
    }

    /// The attached tier's writeback mode, if any.
    pub fn disk_writeback(&self) -> Option<DiskWriteback> {
        self.disk.as_ref().map(|d| d.writeback)
    }

    /// Unbounded tier (eval harness / tests).
    pub fn unbounded() -> HostDocCache {
        Self::new(usize::MAX)
    }

    /// Raise an auto-sized tier's budget to at least `bytes` (engines
    /// call this at init with a budget derived from model geometry).
    /// No-op when the budget was set explicitly, or already larger.
    pub fn ensure_min_budget(&self, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        if !g.budget_explicit && g.budget_bytes < bytes {
            g.budget_bytes = bytes;
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().unwrap().budget_bytes
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&hash)
    }

    /// Fetch-or-lease: a hit bumps recency and returns the entry; a
    /// miss registers the hash as in-flight and returns the lease.
    /// `tokens` are the requested document's ids — an entry stored
    /// under the hash with *different* tokens is a collision and reads
    /// as a miss (see the module docs). Blocks while another thread
    /// holds the hash's lease (their publish becomes our hit — the
    /// exactly-once contract).
    /// Associated fn (not a method): the lease must hold the `Arc`.
    pub fn lookup_or_begin(host: &Arc<HostDocCache>, hash: u64,
                           tokens: &[i32]) -> HostLookup {
        let mut g = host.inner.lock().unwrap();
        loop {
            {
                let inner = &mut *g;
                match inner.entries.get_mut(&hash) {
                    Some(slot) if slot.entry.tokens == tokens => {
                        inner.clock += 1;
                        slot.last_use = inner.clock;
                        inner.stats.hits += 1;
                        return HostLookup::Hit(Arc::clone(&slot.entry));
                    }
                    // same hash, different document: fall through to
                    // the miss path — the caller's publish replaces
                    // the colliding entry
                    Some(_) => inner.stats.hash_collisions += 1,
                    None => {}
                }
                if !inner.in_flight.contains(&hash) {
                    inner.stats.misses += 1;
                    inner.in_flight.insert(hash);
                    return HostLookup::Miss(PrefillLease {
                        host: Arc::clone(host),
                        hash,
                        done: false,
                    });
                }
            }
            // someone else holds the lease: wait for their publish (or
            // abandonment) and retry
            g = host.published.wait(g).unwrap();
        }
    }

    /// Non-leasing lookup (counts a hit or a miss, never blocks).
    /// Collision-checked like [`Self::lookup_or_begin`].
    pub fn try_lookup(&self, hash: u64, tokens: &[i32])
                      -> Option<Arc<DocEntry>> {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        match inner.entries.get_mut(&hash) {
            Some(slot) if slot.entry.tokens == tokens => {
                inner.clock += 1;
                slot.last_use = inner.clock;
                inner.stats.hits += 1;
                Some(Arc::clone(&slot.entry))
            }
            Some(_) => {
                inner.stats.hash_collisions += 1;
                inner.stats.misses += 1;
                None
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Insert an entry directly (tests / replay / lease-less callers).
    /// Replacing an existing hash subtracts the old entry's bytes —
    /// duplicate inserts never inflate the accounting.
    pub fn publish(&self, entry: Arc<DocEntry>) {
        let evicted = {
            let mut g = self.inner.lock().unwrap();
            Self::insert_locked(&mut g, Arc::clone(&entry));
            self.evict_to_budget_locked(&mut g)
        };
        self.published.notify_all();
        self.writeback(Some(&entry), &evicted);
    }

    /// Complete (or abandon) a lease; called by [`PrefillLease`].
    fn finish_lease(&self, hash: u64, entry: Option<Arc<DocEntry>>) {
        let evicted = {
            let mut g = self.inner.lock().unwrap();
            g.in_flight.remove(&hash);
            match &entry {
                Some(e) => {
                    Self::insert_locked(&mut g, Arc::clone(e));
                    self.evict_to_budget_locked(&mut g)
                }
                None => Vec::new(),
            }
        };
        self.published.notify_all();
        self.writeback(entry.as_ref(), &evicted);
    }

    /// Apply the disk writeback policy after an insert/eviction pass
    /// (outside the host lock — file writes must not stall lookups):
    /// write-through persists the fresh insert immediately; both
    /// write modes persist eviction victims (spill), and the disk
    /// tier's content addressing makes the overlap free. Write errors
    /// are logged and dropped — losing a spill only costs a future
    /// recompute, never correctness.
    fn writeback(&self, inserted: Option<&Arc<DocEntry>>,
                 evicted: &[Arc<DocEntry>]) {
        let Some(d) = &self.disk else { return };
        if d.writeback == DiskWriteback::Off {
            return;
        }
        if d.writeback == DiskWriteback::Through {
            if let Some(e) = inserted {
                if let Err(err) = d.cache.store(e) {
                    crate::warn!("disk write-through failed for \
                                  {:016x}: {err:#}", e.hash);
                }
            }
        }
        for e in evicted {
            if let Err(err) = d.cache.store(e) {
                crate::warn!("disk spill failed for {:016x}: {err:#}",
                             e.hash);
            }
        }
    }

    fn insert_locked(g: &mut HostInner, entry: Arc<DocEntry>) {
        g.clock += 1;
        let clock = g.clock;
        let (hash, bytes) = (entry.hash, entry.bytes);
        let replaced = g
            .entries
            .insert(hash, HostSlot { entry, last_use: clock })
            .map(|old| old.entry.bytes);
        g.stats.note_insert(bytes, replaced);
    }

    /// Evict down to the byte budget; returns the victims so the
    /// caller can spill them to the disk tier after the lock drops.
    fn evict_to_budget_locked(&self, g: &mut HostInner)
                              -> Vec<Arc<DocEntry>> {
        let mut victims = Vec::new();
        if g.stats.current_bytes <= g.budget_bytes {
            return victims;
        }
        // build the unpinned candidate list once; the lock is held for
        // the whole pass, so only our own removals invalidate it
        let pins = &g.pins;
        let mut candidates: Vec<EvictionCandidate> = g
            .entries
            .iter()
            .filter(|e| pins.get(e.0).copied().unwrap_or(0) == 0)
            .map(|(&h, s)| EvictionCandidate {
                hash: h,
                bytes: s.entry.bytes,
                last_use: s.last_use,
                recompute_cost: s.entry.tokens.len(),
            })
            .collect();
        while g.stats.current_bytes > g.budget_bytes
            && g.entries.len() > 1
        {
            let Some(victim) = self.policy.pick_victim(&candidates) else {
                break; // everything pinned (or policy refused)
            };
            candidates.retain(|c| c.hash != victim);
            let Some(slot) = g.entries.remove(&victim) else { break };
            g.stats.current_bytes -= slot.entry.bytes;
            g.stats.evictions += 1;
            victims.push(slot.entry);
        }
        victims
    }

    pub fn is_pinned(&self, hash: u64) -> bool {
        self.inner.lock().unwrap().pins.get(&hash).copied().unwrap_or(0)
            > 0
    }

    /// Snapshot of every currently pinned hash (one lock acquisition —
    /// for eviction passes that filter many candidates).
    pub fn pinned_hashes(&self) -> HashSet<u64> {
        self.inner.lock().unwrap().pins.keys().copied().collect()
    }

    fn unpin(&self, hashes: &[u64]) {
        let mut g = self.inner.lock().unwrap();
        for &h in hashes {
            if let Some(c) = g.pins.get_mut(&h) {
                *c -= 1;
                if *c == 0 {
                    g.pins.remove(&h);
                }
            }
        }
    }

    /// Drop every entry **without** spilling (a deliberate drop, not an
    /// eviction — the disk tier keeps whatever was already written).
    /// Lifetime counters and `peak_bytes` survive; `current_bytes`
    /// resets (see the module docs). Outstanding pins and leases are
    /// untouched.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.entries.clear();
        g.stats.current_bytes = 0;
    }

    /// Zero the lifetime counters too (peak collapses to current).
    pub fn reset_stats(&self) {
        self.inner.lock().unwrap().stats.reset_lifetime();
    }
}

/// Exclusive right (and obligation) to prefill one document. Publish
/// the result with [`PrefillLease::publish`]; dropping the lease
/// without publishing (prefill error, panic) abandons it so blocked
/// waiters retry instead of hanging.
pub struct PrefillLease {
    host: Arc<HostDocCache>,
    hash: u64,
    done: bool,
}

impl PrefillLease {
    pub fn hash(&self) -> u64 {
        self.hash
    }

    pub fn publish(mut self, entry: Arc<DocEntry>) {
        self.done = true;
        self.host.finish_lease(self.hash, Some(entry));
    }
}

impl Drop for PrefillLease {
    fn drop(&mut self) {
        if !self.done {
            self.host.finish_lease(self.hash, None);
        }
    }
}

/// Counted pin registry shared between an [`EngineDocCache`] and the
/// [`PinGuard`]s it hands out (the guard outlives the borrow of the
/// cache, so the registry is refcounted).
type PinMap = Arc<Mutex<HashMap<u64, u32>>>;

fn pin_map_remove(map: &PinMap, hashes: &[u64]) {
    let mut m = map.lock().unwrap();
    for &h in hashes {
        if let Some(c) = m.get_mut(&h) {
            *c -= 1;
            if *c == 0 {
                m.remove(&h);
            }
        }
    }
}

/// RAII pin over a set of document hashes. Held by in-flight sessions
/// (and the engine batch loop) over their planned `doc_hashes` so
/// eviction can never race a live assemble. The host tier honors
/// every engine's pins (its entries are shared); a residency tier
/// honors only its **own** engine's pins — evicting another engine's
/// resident copy can never invalidate `Arc`-held documents, and must
/// not be blockable cross-engine.
pub struct PinGuard {
    host: Arc<HostDocCache>,
    /// The pinning engine's own residency-tier pin registry.
    local: Option<PinMap>,
    hashes: Vec<u64>,
}

impl PinGuard {
    /// Pin `hashes` in `host` against eviction until the guard drops.
    /// Hashes not yet present are pinned prospectively (a publish
    /// racing the pin is still protected). Reentrant: pins are
    /// counted.
    pub fn new(host: Arc<HostDocCache>, hashes: &[u64]) -> PinGuard {
        {
            let mut g = host.inner.lock().unwrap();
            for &h in hashes {
                *g.pins.entry(h).or_insert(0) += 1;
            }
        }
        PinGuard { host, local: None, hashes: hashes.to_vec() }
    }

    /// [`Self::new`] plus a pin in the issuing engine's own registry
    /// (see [`EngineDocCache::pin_planned`]).
    fn with_local(host: Arc<HostDocCache>, local: PinMap,
                  hashes: &[u64]) -> PinGuard {
        {
            let mut m = local.lock().unwrap();
            for &h in hashes {
                *m.entry(h).or_insert(0) += 1;
            }
        }
        let mut guard = PinGuard::new(host, hashes);
        guard.local = Some(local);
        guard
    }

    pub fn hashes(&self) -> &[u64] {
        &self.hashes
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.host.unpin(&self.hashes);
        if let Some(local) = &self.local {
            pin_map_remove(local, &self.hashes);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-engine residency tier
// ---------------------------------------------------------------------------

/// Where a [`EngineDocCache::get_or_prefill`] found the document.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierHit {
    /// Already device-resident on this engine.
    Resident,
    /// Host-tier hit (published by another engine or an earlier
    /// request); promoted to resident without any prefill.
    Host,
    /// Loaded from the persistent disk tier (spilled by an earlier
    /// eviction or a previous process) and re-published to the host
    /// tier — no model prefill ran.
    Disk,
    /// Cold everywhere: this call ran the prefill and published it.
    Prefilled,
}

impl TierHit {
    /// Cache-warm semantics: did the request avoid a fresh prefill?
    pub fn is_warm(self) -> bool {
        self != TierHit::Prefilled
    }
}

struct ResidentSlot {
    entry: Arc<DocEntry>,
    last_use: u64,
}

/// One engine's residency tier over the shared host tier. Not
/// thread-safe by itself — it lives on the engine thread, like the
/// model; all cross-engine sharing happens through the host tier.
pub struct EngineDocCache {
    host: Arc<HostDocCache>,
    resident: HashMap<u64, ResidentSlot>,
    clock: u64,
    budget_bytes: usize,
    policy: Box<dyn EvictionPolicy>,
    stats: CacheStats,
    /// Snapshot at the last [`Self::take_stats_delta`] flush.
    flushed: CacheStats,
    residency: Option<ResidencyHandle>,
    /// This engine's own pins (see [`PinGuard`]): the only pins its
    /// residency eviction honors.
    own_pins: PinMap,
}

impl EngineDocCache {
    pub fn new(host: Arc<HostDocCache>, budget_bytes: usize)
               -> EngineDocCache {
        Self::with_policy(host, budget_bytes, Box::new(LruPolicy))
    }

    pub fn with_policy(host: Arc<HostDocCache>, budget_bytes: usize,
                       policy: Box<dyn EvictionPolicy>) -> EngineDocCache {
        EngineDocCache {
            host,
            resident: HashMap::new(),
            clock: 0,
            budget_bytes,
            policy,
            stats: CacheStats::default(),
            flushed: CacheStats::default(),
            residency: None,
            own_pins: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Advertise residency changes on a shared board (router
    /// cache-aware placement).
    pub fn with_residency(mut self, handle: Option<ResidencyHandle>)
                          -> EngineDocCache {
        self.residency = handle;
        self
    }

    /// Self-contained unbounded store (eval harness, examples, tests):
    /// a private unbounded host tier beneath an unbounded residency
    /// tier.
    pub fn unbounded() -> EngineDocCache {
        Self::new(Arc::new(HostDocCache::unbounded()), usize::MAX)
    }

    pub fn host(&self) -> &Arc<HostDocCache> {
        &self.host
    }

    /// This engine's residency-tier stats.
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Snapshot of the shared host tier's stats.
    pub fn host_stats(&self) -> CacheStats {
        self.host.stats()
    }

    /// Residency-tier counters accumulated since the previous call
    /// (`current_bytes`/`peak_bytes` are absolute). The engine flushes
    /// these into [`crate::metrics::Metrics`] after every batch.
    pub fn take_stats_delta(&mut self) -> CacheStats {
        let d = CacheStats {
            hits: self.stats.hits.saturating_sub(self.flushed.hits),
            misses: self.stats.misses.saturating_sub(self.flushed.misses),
            evictions: self
                .stats
                .evictions
                .saturating_sub(self.flushed.evictions),
            publishes: self
                .stats
                .publishes
                .saturating_sub(self.flushed.publishes),
            reinserts: self
                .stats
                .reinserts
                .saturating_sub(self.flushed.reinserts),
            hash_collisions: self
                .stats
                .hash_collisions
                .saturating_sub(self.flushed.hash_collisions),
            current_bytes: self.stats.current_bytes,
            peak_bytes: self.stats.peak_bytes,
        };
        self.flushed = self.stats.clone();
        d
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    /// Resident on this engine (the host tier may hold more).
    pub fn contains(&self, tokens: &[i32]) -> bool {
        self.resident.contains_key(&doc_hash(tokens))
    }

    /// Pin the planned hashes for the lifetime of the returned guard:
    /// globally in the host tier, and locally for this engine's own
    /// residency eviction (see [`PinGuard`]).
    pub fn pin_planned(&self, hashes: &[u64]) -> PinGuard {
        PinGuard::with_local(Arc::clone(&self.host),
                             Arc::clone(&self.own_pins), hashes)
    }

    /// Resident-tier probe with the collision check: `Some` only when
    /// the stored token ids match the requested document.
    fn resident_hit(&mut self, hash: u64, tokens: &[i32])
                    -> Option<Arc<DocEntry>> {
        let slot = self.resident.get_mut(&hash)?;
        if slot.entry.tokens != tokens {
            self.stats.hash_collisions += 1;
            return None;
        }
        slot.last_use = self.clock;
        self.stats.hits += 1;
        Some(Arc::clone(&slot.entry))
    }

    /// Fetch the document's KV cache: resident tier, then the shared
    /// host tier, then — under an exactly-once lease — the persistent
    /// disk tier, then prefill (at local positions, offset 0 — the
    /// multiple-context regime), publishing the result back to the
    /// host tier either way.
    pub fn get_or_prefill(&mut self, model: &Model, tokens: &[i32])
                          -> Result<(Arc<DocEntry>, TierHit)> {
        let h = doc_hash(tokens);
        self.clock += 1;
        if let Some(entry) = self.resident_hit(h, tokens) {
            return Ok((entry, TierHit::Resident));
        }
        self.stats.misses += 1;
        match HostDocCache::lookup_or_begin(&self.host, h, tokens) {
            HostLookup::Hit(entry) => {
                self.admit(Arc::clone(&entry));
                Ok((entry, TierHit::Host))
            }
            HostLookup::Miss(lease) => {
                // the lease serializes both the disk read and the
                // prefill: each absent document is materialized at
                // most once process-wide, whichever source supplies it
                let disk = self.host.disk().cloned();
                if let Some(disk) = disk {
                    if let Some(entry) = disk.load(h, tokens) {
                        lease.publish(Arc::clone(&entry));
                        self.admit(Arc::clone(&entry));
                        return Ok((entry, TierHit::Disk));
                    }
                }
                // prefill outside any lock; on error the lease drop
                // wakes waiters to retry for themselves
                let out = model.prefill_doc(tokens, 0)?;
                let entry = Arc::new(DocEntry::new(tokens.to_vec(), out));
                lease.publish(Arc::clone(&entry));
                self.admit(Arc::clone(&entry));
                Ok((entry, TierHit::Prefilled))
            }
        }
    }

    /// Model-free lookup: resident tier, then host tier, then the
    /// persistent disk tier (promoting a hit to resident and — for a
    /// disk hit — re-publishing it to the host tier); `None` on a true
    /// miss.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<Arc<DocEntry>> {
        let h = doc_hash(tokens);
        self.clock += 1;
        if let Some(entry) = self.resident_hit(h, tokens) {
            return Some(entry);
        }
        self.stats.misses += 1;
        if let Some(entry) = self.host.try_lookup(h, tokens) {
            self.admit(Arc::clone(&entry));
            return Some(entry);
        }
        let disk = self.host.disk().cloned()?;
        let entry = disk.load(h, tokens)?;
        self.host.publish(Arc::clone(&entry));
        self.admit(Arc::clone(&entry));
        Some(entry)
    }

    /// Warm the host tier from the persistent disk tier for a set of
    /// planned documents. The engine's admission thread calls this on
    /// a wave's deduplicated doc hashes *while the decode thread keeps
    /// emitting tokens*, so disk load latency overlaps decode compute
    /// the same way assemble does. Documents already resident or
    /// host-cached are skipped; returns how many entries disk
    /// supplied. (Prefetch is leaseless — two engines racing on one
    /// hash can at worst duplicate a file read, never a prefill.)
    pub fn prefetch_from_disk(&mut self, docs: &[(u64, &[i32])]) -> usize {
        let Some(disk) = self.host.disk().cloned() else { return 0 };
        let mut loaded = 0;
        for &(hash, tokens) in docs {
            if self.resident.contains_key(&hash)
                || self.host.contains(hash)
            {
                continue;
            }
            if let Some(entry) = disk.load(hash, tokens) {
                self.host.publish(Arc::clone(&entry));
                self.admit(entry);
                loaded += 1;
            }
        }
        loaded
    }

    /// Insert a pre-computed entry (tests / replay): published to the
    /// host tier and admitted as resident here.
    pub fn insert(&mut self, tokens: Vec<i32>, out: PrefillDocOut) {
        self.insert_entry(Arc::new(DocEntry::new(tokens, out)));
    }

    /// [`Self::insert`] over an already-built entry (disk replay,
    /// forged-collision tests).
    pub fn insert_entry(&mut self, entry: Arc<DocEntry>) {
        self.host.publish(Arc::clone(&entry));
        self.admit(entry);
    }

    /// Make an entry device-resident, with the duplicate-insert byte
    /// accounting fix: replacing an existing hash subtracts the old
    /// entry's bytes first.
    fn admit(&mut self, entry: Arc<DocEntry>) {
        let (h, bytes) = (entry.hash, entry.bytes);
        self.clock += 1;
        let replaced = self
            .resident
            .insert(h, ResidentSlot { entry, last_use: self.clock })
            .map(|old| old.entry.bytes);
        if replaced.is_none() {
            if let Some(r) = &self.residency {
                r.insert(h);
            }
        }
        self.stats.note_insert(bytes, replaced);
        self.evict_to_budget();
    }

    fn evict_to_budget(&mut self) {
        if self.stats.current_bytes <= self.budget_bytes {
            return;
        }
        // only this engine's own pins matter here: evicting our
        // resident copy never invalidates Arc-held docs, and another
        // engine's session must not be able to wedge us over our
        // device budget. One snapshot for the whole pass.
        let pinned: HashSet<u64> =
            self.own_pins.lock().unwrap().keys().copied().collect();
        let mut candidates: Vec<EvictionCandidate> = self
            .resident
            .iter()
            .filter(|e| !pinned.contains(e.0))
            .map(|(&h, s)| EvictionCandidate {
                hash: h,
                bytes: s.entry.bytes,
                last_use: s.last_use,
                recompute_cost: s.entry.tokens.len(),
            })
            .collect();
        while self.stats.current_bytes > self.budget_bytes
            && self.resident.len() > 1
        {
            let Some(victim) = self.policy.pick_victim(&candidates) else {
                break;
            };
            candidates.retain(|c| c.hash != victim);
            let Some(slot) = self.resident.remove(&victim) else { break };
            self.stats.current_bytes -= slot.entry.bytes;
            self.stats.evictions += 1;
            if let Some(r) = &self.residency {
                r.remove(victim);
            }
        }
    }

    /// Drop this engine's residency (the host tier keeps its entries).
    /// Lifetime counters and `peak_bytes` survive; `current_bytes`
    /// resets (see the module docs).
    pub fn clear(&mut self) {
        if let Some(r) = &self.residency {
            r.clear();
        }
        self.resident.clear();
        self.stats.current_bytes = 0;
    }

    /// Drop residency **and** the backing host tier's entries (eval
    /// harness memory bound between disjoint sample sets).
    pub fn clear_all(&mut self) {
        self.clear();
        self.host.clear();
    }

    /// Zero the lifetime counters too (peak collapses to current).
    pub fn reset_stats(&mut self) {
        self.stats.reset_lifetime();
        self.flushed = self.stats.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PrefillDocOut;

    fn fake_entry(bytes_hint: usize) -> PrefillDocOut {
        // bytes = (kv + attn + q_local) * 4; use kv only for sizing
        PrefillDocOut {
            kv: Tensor::zeros(&[1, 2, 1, bytes_hint / 8, 1]),
            attn: Tensor::zeros(&[1, 1, 1, 1]),
            q_local: Tensor::zeros(&[1, 1, 1]),
        }
    }

    fn arc_entry(tokens: Vec<i32>, bytes_hint: usize) -> Arc<DocEntry> {
        Arc::new(DocEntry::new(tokens, fake_entry(bytes_hint)))
    }

    #[test]
    fn hash_is_content_based() {
        assert_eq!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 3]));
        assert_ne!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 4]));
        assert_ne!(doc_hash(&[1, 2]), doc_hash(&[2, 1]));
    }

    #[test]
    fn doc_hash_is_fnv64_over_le_bytes() {
        // the streamed doc hash and the byte-level fnv64 (disk-tier
        // checksum) must stay bit-identical
        let tokens = [7i32, -3, 65_536];
        let bytes: Vec<u8> =
            tokens.iter().flat_map(|t| t.to_le_bytes()).collect();
        assert_eq!(doc_hash(&tokens), fnv64(&bytes));
        assert_eq!(doc_hash(&[]), fnv64(&[]));
    }

    #[test]
    fn insert_and_contains() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1, 2, 3], fake_entry(64));
        assert!(s.contains(&[1, 2, 3]));
        assert!(!s.contains(&[9, 9]));
        assert_eq!(s.len(), 1);
        assert_eq!(s.host().len(), 1);
        assert!(s.stats().current_bytes > 0);
        assert_eq!(s.host_stats().current_bytes,
                   s.stats().current_bytes);
    }

    #[test]
    fn duplicate_insert_does_not_leak_bytes() {
        // the seed bug: re-inserting an existing hash inflated
        // current_bytes forever; both tiers must subtract the old entry
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1, 2], fake_entry(128));
        let once = s.stats().current_bytes;
        s.insert(vec![1, 2], fake_entry(128));
        assert_eq!(s.stats().current_bytes, once,
                   "residency tier leaked duplicate-insert bytes");
        assert_eq!(s.stats().reinserts, 1);
        assert_eq!(s.host_stats().current_bytes, once,
                   "host tier leaked duplicate-insert bytes");
        assert_eq!(s.host_stats().reinserts, 1);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each entry: kv 32 elems (128B) + attn 4B + q_local 4B = 136B
        let host = Arc::new(HostDocCache::unbounded());
        let mut s = EngineDocCache::new(Arc::clone(&host), 300);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        assert_eq!(s.len(), 2);
        s.insert(vec![3], fake_entry(128));
        assert!(s.stats().evictions >= 1);
        assert!(s.stats().current_bytes <= 300);
        // entry 1 was the LRU victim — resident no longer, but the
        // unbounded host tier still holds it (tiering, not loss)
        assert!(!s.contains(&[1]));
        assert!(s.contains(&[3]));
        assert!(host.contains(doc_hash(&[1])));
        assert!(s.lookup(&[1]).is_some(), "host tier must backfill");
    }

    #[test]
    fn host_eviction_skips_pinned_entries() {
        let host = Arc::new(HostDocCache::new(300));
        let e1 = arc_entry(vec![1], 128);
        let pin = PinGuard::new(Arc::clone(&host), &[e1.hash]);
        host.publish(e1);
        host.publish(arc_entry(vec![2], 128));
        host.publish(arc_entry(vec![3], 128)); // over budget
        assert!(host.stats().evictions >= 1);
        assert!(host.contains(doc_hash(&[1])),
                "pinned entry was evicted");
        assert!(!host.contains(doc_hash(&[2])),
                "LRU unpinned entry should have been the victim");
        drop(pin);
        assert!(!host.is_pinned(doc_hash(&[1])));
        host.publish(arc_entry(vec![4], 128)); // over budget again
        assert!(!host.contains(doc_hash(&[1])),
                "unpinned entry must become evictable");
    }

    #[test]
    fn resident_eviction_skips_own_pinned_entries() {
        let host = Arc::new(HostDocCache::unbounded());
        let mut s = EngineDocCache::new(Arc::clone(&host), 300);
        let pinned_hash = doc_hash(&[1]);
        let _pin = s.pin_planned(&[pinned_hash]);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        s.insert(vec![3], fake_entry(128));
        assert!(s.contains(&[1]), "pinned entry evicted from residency");
        assert!(!s.contains(&[2]));
    }

    #[test]
    fn resident_eviction_ignores_other_engines_pins() {
        // engine A's session pins must not wedge engine B over its
        // device budget: B may evict its own copy (A's Arc-held docs
        // and the host entry are untouched)
        let host = Arc::new(HostDocCache::unbounded());
        let a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let mut b = EngineDocCache::new(Arc::clone(&host), 300);
        let _pin = a.pin_planned(&[doc_hash(&[1])]);
        b.insert(vec![1], fake_entry(128));
        b.insert(vec![2], fake_entry(128));
        b.insert(vec![3], fake_entry(128));
        assert!(b.stats().current_bytes <= 300,
                "cross-engine pin wedged B over its budget");
        assert!(!b.contains(&[1]), "B's own LRU copy must be evictable");
        assert!(host.contains(doc_hash(&[1])),
                "the shared host entry honors A's pin");
        assert!(host.is_pinned(doc_hash(&[1])));
    }

    #[test]
    fn cross_engine_host_tier_hit() {
        // engine B hits what engine A published, without any prefill
        let host = Arc::new(HostDocCache::unbounded());
        let mut a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let mut b = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        a.insert(vec![7, 8], fake_entry(64));
        assert!(!b.contains(&[7, 8]));
        let hit = b.lookup(&[7, 8]).expect("host tier hit");
        assert_eq!(hit.hash, doc_hash(&[7, 8]));
        assert!(b.contains(&[7, 8]), "host hit promotes to resident");
        assert_eq!(host.stats().hits, 1);
        assert_eq!(b.stats().misses, 1); // residency miss, host hit
        assert!(b.lookup(&[9]).is_none());
    }

    #[test]
    fn lease_lifecycle_is_exactly_once() {
        let host = Arc::new(HostDocCache::unbounded());
        let h = doc_hash(&[5]);
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[5])
        else {
            panic!("expected miss");
        };
        assert_eq!(lease.hash(), h);
        lease.publish(arc_entry(vec![5], 64));
        match HostDocCache::lookup_or_begin(&host, h, &[5]) {
            HostLookup::Hit(e) => assert_eq!(e.hash, h),
            HostLookup::Miss(_) => panic!("published entry must hit"),
        }
        assert_eq!(host.stats().publishes, 1);
        // abandoned lease (failed prefill) re-opens the hash
        let h2 = doc_hash(&[6]);
        let HostLookup::Miss(lease2) =
            HostDocCache::lookup_or_begin(&host, h2, &[6])
        else {
            panic!("expected miss");
        };
        drop(lease2);
        assert!(matches!(
            HostDocCache::lookup_or_begin(&host, h2, &[6]),
            HostLookup::Miss(_)
        ));
    }

    #[test]
    fn concurrent_leases_block_until_publish() {
        let host = Arc::new(HostDocCache::unbounded());
        let h = doc_hash(&[42]);
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[42])
        else {
            panic!("expected miss");
        };
        let waiter = {
            let host = Arc::clone(&host);
            std::thread::spawn(move || {
                match HostDocCache::lookup_or_begin(&host, h, &[42]) {
                    HostLookup::Hit(e) => e.hash,
                    HostLookup::Miss(_) => panic!("waiter must see the \
                                                   publish, not prefill"),
                }
            })
        };
        // give the waiter time to block on the in-flight lease
        std::thread::sleep(std::time::Duration::from_millis(20));
        lease.publish(arc_entry(vec![42], 64));
        assert_eq!(waiter.join().unwrap(), h);
        assert_eq!(host.stats().publishes, 1);
        assert_eq!(host.stats().hits, 1);
    }

    #[test]
    fn clear_keeps_lifetime_counters() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(128));
        let _ = s.lookup(&[1]);
        let _ = s.lookup(&[9]); // miss
        s.clear_all();
        assert_eq!(s.stats().current_bytes, 0);
        assert_eq!(s.host_stats().current_bytes, 0);
        assert_eq!(s.len(), 0);
        // lifetime counters survive clear...
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.stats().misses, 1);
        assert_eq!(s.stats().publishes, 1);
        assert!(s.stats().peak_bytes > 0);
        // ...and reset_stats zeroes them
        s.reset_stats();
        s.host().reset_stats();
        assert_eq!(*s.stats(), CacheStats::default());
        assert_eq!(s.host_stats(), CacheStats::default());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(128));
        let p1 = s.stats().peak_bytes;
        s.insert(vec![2], fake_entry(128));
        assert!(s.stats().peak_bytes > p1);
        s.clear();
        assert_eq!(s.stats().current_bytes, 0);
        assert!(s.stats().peak_bytes > p1);
    }

    #[test]
    fn stats_delta_accumulates_between_flushes() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(64));
        let _ = s.lookup(&[1]);
        let d1 = s.take_stats_delta();
        assert_eq!((d1.hits, d1.publishes), (1, 1));
        let d2 = s.take_stats_delta();
        assert_eq!((d2.hits, d2.publishes), (0, 0));
        assert_eq!(d2.current_bytes, s.stats().current_bytes);
        let _ = s.lookup(&[1]);
        assert_eq!(s.take_stats_delta().hits, 1);
    }

    #[test]
    fn auto_sized_budget_raised_by_engines_only() {
        let auto = HostDocCache::auto_sized(Box::new(LruPolicy));
        assert_eq!(auto.budget_bytes(), 0);
        auto.ensure_min_budget(1024);
        auto.ensure_min_budget(512); // never lowers
        assert_eq!(auto.budget_bytes(), 1024);
        // an explicit budget is the operator's word: ensure_min is a
        // no-op
        let fixed = HostDocCache::new(300);
        fixed.ensure_min_budget(1 << 30);
        assert_eq!(fixed.budget_bytes(), 300);
    }

    #[test]
    fn tier_hit_warmth() {
        assert!(TierHit::Resident.is_warm());
        assert!(TierHit::Host.is_warm());
        assert!(TierHit::Disk.is_warm());
        assert!(!TierHit::Prefilled.is_warm());
    }

    /// An entry whose `hash` field deliberately disagrees with its
    /// token content — two documents colliding on one content hash.
    fn forged(hash: u64, tokens: Vec<i32>) -> Arc<DocEntry> {
        let e = DocEntry::new(tokens, fake_entry(64));
        Arc::new(DocEntry { hash, ..e })
    }

    #[test]
    fn host_collision_is_a_miss_not_a_wrong_hit() {
        // the hash of the document we will ask for, occupied by a
        // *different* document's entry
        let h = doc_hash(&[1, 2, 3]);
        let host = Arc::new(HostDocCache::unbounded());
        host.publish(forged(h, vec![9, 9]));
        assert!(host.try_lookup(h, &[1, 2, 3]).is_none(),
                "collision served another document's KV");
        let s = host.stats();
        assert_eq!(s.hash_collisions, 1);
        assert_eq!(s.misses, 1);
        // the stored document itself still hits
        assert!(host.try_lookup(h, &[9, 9]).is_some());
        // the leasing path also treats the collision as a miss, and
        // its publish replaces the colliding entry (reinsert, no leak)
        let HostLookup::Miss(lease) =
            HostDocCache::lookup_or_begin(&host, h, &[1, 2, 3])
        else {
            panic!("collision must fall through to a lease");
        };
        lease.publish(forged(h, vec![1, 2, 3]));
        assert!(host.try_lookup(h, &[1, 2, 3]).is_some());
        assert_eq!(host.stats().reinserts, 1);
        assert_eq!(host.len(), 1);
    }

    #[test]
    fn resident_collision_is_a_miss_not_a_wrong_hit() {
        let h = doc_hash(&[1, 2, 3]);
        let mut s = EngineDocCache::unbounded();
        s.insert_entry(forged(h, vec![9, 9]));
        // both the resident slot and the host entry hold [9,9] under
        // the hash of [1,2,3]: the lookup must come back empty
        assert!(s.lookup(&[1, 2, 3]).is_none(),
                "collision served another document's KV");
        assert_eq!(s.stats().hash_collisions, 1);
        assert_eq!(s.host_stats().hash_collisions, 1);
    }

    fn disk_fixture(tag: &str) -> (std::path::PathBuf, Arc<DiskDocCache>) {
        let dir = std::env::temp_dir().join(format!(
            "samkv-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let disk = Arc::new(DiskDocCache::open(&dir, usize::MAX).unwrap());
        (dir, disk)
    }

    #[test]
    fn host_eviction_spills_to_disk_and_reloads() {
        let (dir, disk) = disk_fixture("spill");
        // each entry is 136B; a 300B host budget evicts the LRU on the
        // third publish — the victim must land on disk, not vanish
        let host = Arc::new(HostDocCache::new(300)
            .with_disk(Arc::clone(&disk), DiskWriteback::Evict));
        let mut a = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        a.insert(vec![1], fake_entry(128));
        a.insert(vec![2], fake_entry(128));
        a.insert(vec![3], fake_entry(128));
        assert!(host.stats().evictions >= 1);
        assert!(!host.contains(doc_hash(&[1])));
        assert!(disk.contains(doc_hash(&[1])),
                "evicted entry must spill to the disk tier");
        assert_eq!(disk.stats().spills, 1,
                   "evict mode only writes victims");
        // a cold engine re-loads the spilled entry through the tiers
        let mut b = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        let e = b.lookup(&[1]).expect("disk tier must backfill");
        assert_eq!(e.tokens, vec![1]);
        assert_eq!(disk.stats().hits, 1);
        assert!(host.contains(doc_hash(&[1])),
                "disk hit must re-publish to the host tier");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_through_persists_on_publish() {
        let (dir, disk) = disk_fixture("through");
        let host = Arc::new(HostDocCache::unbounded()
            .with_disk(Arc::clone(&disk), DiskWriteback::Through));
        assert_eq!(host.disk_writeback(), Some(DiskWriteback::Through));
        host.publish(arc_entry(vec![4], 128));
        assert!(disk.contains(doc_hash(&[4])),
                "write-through must persist the insert immediately");
        assert_eq!(disk.stats().spills, 1);
        // re-publishing the same content is one write total
        host.publish(arc_entry(vec![4], 128));
        assert_eq!(disk.stats().spills, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writeback_off_never_writes_but_still_reads() {
        let (dir, disk) = disk_fixture("off");
        // pre-seed the directory as if by an earlier process
        disk.store(&DocEntry::new(vec![8, 8], fake_entry(64))).unwrap();
        let host = Arc::new(HostDocCache::new(300)
            .with_disk(Arc::clone(&disk), DiskWriteback::Off));
        let mut s = EngineDocCache::new(Arc::clone(&host), usize::MAX);
        s.insert(vec![1], fake_entry(128));
        s.insert(vec![2], fake_entry(128));
        s.insert(vec![3], fake_entry(128)); // host evicts, no spill
        assert_eq!(disk.stats().spills, 1, "off mode must never write");
        // ...but the pre-seeded entry is still readable
        assert!(s.lookup(&[8, 8]).is_some());
        assert_eq!(disk.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reset_stats_resets_flush_baseline() {
        let mut s = EngineDocCache::unbounded();
        s.insert(vec![1], fake_entry(64));
        let _ = s.lookup(&[1]);
        assert_eq!(s.take_stats_delta().hits, 1);
        // regression: a reset between two flushes must reset the flush
        // baseline too — a baseline above the live counters would make
        // every later delta saturate to zero
        s.reset_stats();
        let _ = s.lookup(&[1]);
        let _ = s.lookup(&[1]);
        let d = s.take_stats_delta();
        assert_eq!(d.hits, 2,
                   "post-reset hits swallowed by a stale flush baseline");
        assert_eq!(s.take_stats_delta().hits, 0);
    }
}
