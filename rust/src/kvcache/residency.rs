//! Shared directory of per-engine device residency.
//!
//! Each engine's [`super::EngineDocCache`] advertises which document
//! hashes it currently holds resident; the router reads the board to
//! steer a request toward the engine that already has its documents
//! (cache-aware placement). The board is advisory: a stale read only
//! costs placement quality — the host tier still dedups the actual
//! prefill work — so entries are plain per-engine hash sets behind
//! mutexes, updated on admit/evict. Only *device* residency is
//! advertised: host-tier and persistent disk-tier contents are
//! engine-agnostic (any engine hits them at equal cost), so they
//! never influence placement.
//!
//! Residency advertising stays **doc-granular** even though the host
//! tier beneath it evicts at pool-block granularity: the board answers
//! "which engine should serve this request", and a document whose tail
//! blocks were evicted still makes that engine the cheapest placement
//! (the holes refill from disk or a partial prefill, far cheaper than
//! a cold full prefill elsewhere). An engine only advertises documents
//! it admitted fully resident, and the advisory-staleness argument
//! above already covers the window where blocks leave afterwards.

use std::collections::HashSet;
use std::sync::Arc;

use crate::sync::Mutex;

/// Per-engine sets of device-resident document hashes. Each engine's
/// set is its own `residency-board` lock-class instance (see
/// [`crate::sync`]); the board is a leaf in the canonical acquisition
/// order and out-of-range engine indices read as empty/no-op so a
/// confused caller can never panic the placement path.
#[derive(Debug)]
pub struct ResidencyBoard {
    engines: Vec<Mutex<HashSet<u64>>>,
}

impl ResidencyBoard {
    pub fn new(n_engines: usize) -> ResidencyBoard {
        ResidencyBoard {
            engines: (0..n_engines)
                .map(|_| Mutex::named("residency-board", HashSet::new()))
                .collect(),
        }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    /// How many of `hashes` are resident on `engine`.
    pub fn resident_count(&self, engine: usize, hashes: &[u64]) -> usize {
        let Some(set) = self.engines.get(engine) else {
            return 0;
        };
        let set = set.lock();
        hashes.iter().filter(|h| set.contains(h)).count()
    }

    pub fn is_resident(&self, engine: usize, hash: u64) -> bool {
        self.engines
            .get(engine)
            .is_some_and(|s| s.lock().contains(&hash))
    }

    /// Drop every advertisement for `engine` — called when the router
    /// marks the engine down, so stale residency can no longer pull
    /// placements toward a dead engine.
    pub fn clear_engine(&self, engine: usize) {
        if let Some(set) = self.engines.get(engine) {
            set.lock().clear();
        }
    }
}

/// One engine's write handle onto the board (held by its
/// [`super::EngineDocCache`]).
#[derive(Debug, Clone)]
pub struct ResidencyHandle {
    board: Arc<ResidencyBoard>,
    engine: usize,
}

impl ResidencyHandle {
    /// Writer handle for one engine's residency tier.
    pub fn new(board: Arc<ResidencyBoard>, engine: usize)
               -> ResidencyHandle {
        assert!(engine < board.engines.len());
        ResidencyHandle { board, engine }
    }

    pub fn engine(&self) -> usize {
        self.engine
    }

    pub fn insert(&self, hash: u64) {
        if let Some(set) = self.board.engines.get(self.engine) {
            set.lock().insert(hash);
        }
    }

    pub fn remove(&self, hash: u64) {
        if let Some(set) = self.board.engines.get(self.engine) {
            set.lock().remove(&hash);
        }
    }

    pub fn clear(&self) {
        if let Some(set) = self.board.engines.get(self.engine) {
            set.lock().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_tracks_per_engine_residency() {
        let board = Arc::new(ResidencyBoard::new(2));
        let h0 = ResidencyHandle::new(Arc::clone(&board), 0);
        let h1 = ResidencyHandle::new(Arc::clone(&board), 1);
        h0.insert(10);
        h0.insert(20);
        h1.insert(20);
        assert_eq!(board.resident_count(0, &[10, 20, 30]), 2);
        assert_eq!(board.resident_count(1, &[10, 20, 30]), 1);
        assert!(board.is_resident(0, 10));
        assert!(!board.is_resident(1, 10));
        h0.remove(10);
        assert!(!board.is_resident(0, 10));
        h1.clear();
        assert_eq!(board.resident_count(1, &[20]), 0);
    }

    #[test]
    fn clear_engine_drops_only_that_engine() {
        let board = ResidencyBoard::new(2);
        let b = Arc::new(board);
        ResidencyHandle::new(Arc::clone(&b), 0).insert(1);
        ResidencyHandle::new(Arc::clone(&b), 1).insert(2);
        b.clear_engine(0);
        assert!(!b.is_resident(0, 1));
        assert!(b.is_resident(1, 2));
    }
}
