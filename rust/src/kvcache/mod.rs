//! Multi-context KV cache management.
//!
//! [`store`] — the document cache: content-addressed per-document KV
//! entries (the "multiple-context KV Cache" of the paper: each document
//! prefilled independently at local positions), with ref-counted LRU
//! eviction and byte-accurate memory accounting.
//!
//! [`assembly`] — building the fixed-shape sparse/full buffers the AOT
//! artifacts consume from a set of selected (doc, block) slots.

pub mod assembly;
pub mod store;

pub use assembly::{AssembledContext, BlockRef, SlotKind};
pub use store::{CacheStats, CacheStore, DocEntry};
