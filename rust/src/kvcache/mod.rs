//! Multi-context KV cache management: the tiered document cache and
//! the buffer assembly that consumes it.
//!
//! # The two tiers
//!
//! Document KV caches (the "multiple-context KV Cache" of the paper:
//! each document prefilled independently at local positions) live in a
//! two-tier subsystem so that one engine's prefill is every engine's
//! hit:
//!
//! ```text
//!   engine 0 thread            engine 1 thread         router
//! ┌───────────────────┐     ┌───────────────────┐   placement reads
//! │ EngineDocCache    │     │ EngineDocCache    │   ResidencyBoard
//! │ (residency tier:  │     │ (residency tier:  │◄──────────────────
//! │  device-resident  │     │  own budget, LRU/ │
//! │  subset, own      │     │  cost-aware)      │
//! │  budget)          │     │                   │
//! └─────────┬─────────┘     └─────────┬─────────┘
//!     miss  │  publish          miss  │  publish
//!           ▼                         ▼
//! ┌─────────────────────────────────────────────────┐
//! │ HostDocCache (shared host tier, Arc<DocEntry>)  │
//! │  content-addressed · thread-safe · byte budget  │
//! │  pin guards · prefill leases (exactly-once)     │
//! └─────────────────────────────────────────────────┘
//! ```
//!
//! A [`EngineDocCache::get_or_prefill`] miss consults the shared
//! [`HostDocCache`] before running `model.prefill_doc`; a true miss
//! takes a [`store::PrefillLease`] (concurrent requests for the same
//! document block until it publishes — each unique document is
//! prefilled **exactly once process-wide**) and publishes the fresh
//! entry back to the host tier. Engines advertise their resident
//! hashes on a [`ResidencyBoard`] so the router can prefer the engine
//! that already holds a request's documents.
//!
//! # Pin-guard contract
//!
//! Eviction (pluggable via [`EvictionPolicy`]: [`LruPolicy`] or
//! [`CostAwarePolicy`]) only ever removes **unpinned** entries.
//! In-flight work pins the document hashes it planned
//! ([`store::PinGuard`], from [`EngineDocCache::pin_planned`]) for as
//! long as the guard lives — sessions pin across
//! prefill→assemble→decode, and the engine batch loop pins a whole
//! batch's planned hashes — so eviction can never race a live
//! assemble. The **host tier** honors every engine's pins (its
//! entries are shared); a **residency tier** honors only its own
//! engine's pins, because evicting another engine's resident copy
//! cannot invalidate `Arc`-held documents and must not be blockable
//! cross-engine. An eviction between pins can therefore only ever
//! cost a recompute, never dangle a reference. Pins are counted
//! (re-pinning is fine) and may name hashes that are not published
//! yet.
//!
//! # Stats
//!
//! Each tier keeps its own [`CacheStats`]; `hits`/`misses`/
//! `evictions`/`publishes`/`reinserts`/`peak_bytes` are lifetime
//! counters, `current_bytes` is current state (see [`store`]).
//!
//! [`assembly`] — building the fixed-shape sparse/full buffers the AOT
//! artifacts consume from a set of selected (doc, block) slots.

pub mod assembly;
pub mod evict;
pub mod residency;
pub mod store;

pub use assembly::{AssembledContext, BlockRef, SlotKind};
pub use evict::{
    eviction_policy_by_name, CostAwarePolicy, EvictionCandidate,
    EvictionPolicy, LruPolicy,
};
pub use residency::{ResidencyBoard, ResidencyHandle};
pub use store::{
    doc_hash, CacheStats, DocEntry, EngineDocCache, HostDocCache,
    PinGuard, TierHit,
};
