//! Multi-context KV cache management: the paged block pool, the tiered
//! document cache built on it, and the buffer assembly that consumes
//! both.
//!
//! # The paged block pool
//!
//! All document KV in RAM lives in one process-wide
//! [`pool::KvBlockPool`]: a contiguous f32 slab divided into
//! fixed-size **slots**, each holding a `--kv-block-tokens` span
//! (default [`pool::DEFAULT_KV_BLOCK_TOKENS`]) of every layer's K and
//! V for one document, channel-major. The slab invariants:
//!
//! * **slot = allocation unit.** A free list gives O(1) alloc/free;
//!   the slab grows by doubling (old slots keep their contents and
//!   indices), so there is zero external fragmentation and no
//!   per-document resize copies.
//! * **blocks are refcounted.** A [`pool::BlockRef`] is a shared
//!   handle; clones bump the refcount, the last drop frees the slot.
//!   Identical block payloads are deduplicated content-addressed (two
//!   documents sharing a prefix — or the same document admitted twice
//!   — share slots, verified byte-for-byte before sharing), and
//!   writes to a shared block copy-on-write into a fresh slot.
//! * **a document is a block-index list.** [`pool::KvBlocks`] maps
//!   block index → `Option<BlockRef>`; a `None` is a **hole** (that
//!   block was evicted). Reads ([`pool::KvBlocks::copy_span`],
//!   `gather`) cross slot boundaries transparently and fail cleanly
//!   on holes.
//!
//! # The three tiers
//!
//! Document KV caches (the "multiple-context KV Cache" of the paper:
//! each document prefilled independently at local positions) live in a
//! three-tier storage hierarchy — residency → host → disk — so that
//! one engine's prefill is every engine's hit, and one *process's*
//! prefill survives restarts:
//!
//! ```text
//!   engine 0 thread            engine 1 thread         router
//! ┌───────────────────┐     ┌───────────────────┐   placement reads
//! │ EngineDocCache    │     │ EngineDocCache    │   ResidencyBoard
//! │ (residency tier:  │     │ (residency tier:  │◄──────────────────
//! │  device-resident  │     │  own budget, LRU/ │
//! │  subset, own      │     │  cost-aware)      │
//! │  budget)          │     │                   │
//! └─────────┬─────────┘     └─────────┬─────────┘
//!     miss  │  publish          miss  │  publish
//!           ▼                         ▼
//! ┌─────────────────────────────────────────────────┐
//! │ HostDocCache (shared host tier, Arc<DocEntry>)  │
//! │  content-addressed · thread-safe · byte budget  │
//! │  pin guards · prefill leases (exactly-once)     │
//! │  block-granular eviction over the KvBlockPool   │
//! └───────────────────────┬─────────────────────────┘
//!   block spill on evict  │  block refill on partial hit
//!                         ▼
//! ┌─────────────────────────────────────────────────┐
//! │ DiskDocCache (persistent tier, --disk-cache-dir)│
//! │  per-hash block-list files, per-block checksums │
//! │  own byte budget/eviction · quarantine on error │
//! └─────────────────────────────────────────────────┘
//! ```
//!
//! # Tier crossings are block-granular
//!
//! The tiers exchange **blocks**, not whole documents:
//!
//! * **Host eviction** offers the policy one candidate per resident
//!   `(document, block)` pair and evicts single blocks — a partially
//!   evicted document stays in the host map and still serves its
//!   resident blocks warm; only a document whose last block leaves is
//!   removed. Victim payloads **spill** to the disk tier as block
//!   records ([`crate::config::DiskWriteback`], `--disk-writeback`):
//!   `evict` writes victims as they leave RAM, `through` persists
//!   every host insert immediately, `off` never writes but still
//!   reads. Disk writes run outside the host lock; a failed write is
//!   only ever a lost future shortcut.
//! * **Host lookup** of a partial document refills just the holes
//!   from disk ([`DiskDocCache::load_blocks_into`]); a prefill lease
//!   taken over a partial entry carries it, so the leaseholder
//!   restores blocks instead of re-prefilling the whole document.
//! * **Disk files mirror the block structure** (format v3): a
//!   checksummed metadata section plus one independently checksummed
//!   record per block — each record tagged with the codec that
//!   encoded it (see below) — so a corrupt block quarantines alone
//!   and repeated spills of one document merge toward one complete
//!   file. Format v2 files (untagged raw-f32 records) remain fully
//!   readable. See [`disk`] for the full corruption / staleness
//!   contract.
//! * The **residency tier** stays doc-granular: it holds `Arc`
//!   handles, advertises whole documents on the [`ResidencyBoard`]
//!   (see [`residency`]), and a fully-resident check guards its warm
//!   hits.
//!
//! A [`EngineDocCache::get_or_prefill`] miss consults the shared
//! [`HostDocCache`] before running `model.prefill_doc`; a true miss
//! takes a [`store::PrefillLease`] (concurrent requests for the same
//! document block until it publishes — each unique document is
//! prefilled **exactly once process-wide**), consults the persistent
//! [`DiskDocCache`] under that lease when one is attached, and only
//! prefills when the disk misses too — a restarted server or a cold
//! engine serves a previously-seen document with **zero** model
//! prefills. Fresh entries are published back to the host tier either
//! way, and the engine admission thread prefetches a wave's planned
//! hashes from disk ([`EngineDocCache::prefetch_from_disk`]) while
//! decode keeps running, so disk latency overlaps compute.
//!
//! # The peer tier (`--peers` mode)
//!
//! With `--peers addr0,addr1,… --node-id I` the host tier gains one
//! more rung between disk and prefill: every document hash has exactly
//! one **owning node** under rendezvous hashing
//! (`server::peers::rendezvous_owner` — stable under node-set changes,
//! shared with the front end's placement), and a node whose local
//! tiers all miss a *remotely owned* document asks the owner for the
//! serialized entry over the `peer_get` RPC **under its own prefill
//! lease**, decoding the reply (the checksummed disk-tier v3 wire
//! format, [`entry_from_bytes`]) straight into the block pool. A hit
//! is [`TierHit::Peer`]: warm, zero model prefills here — and, because
//! the owner ran its own exactly-once lease, zero anywhere else. The
//! exactly-once prefill guarantee is thereby **cluster-wide**. Peers
//! exchange only complete entries ([`entry_to_bytes`] /
//! [`HostDocCache::export_wire`] refuse partials); `--disk-writeback
//! off` replicas serve as pre-seeded read-only warm starts. The
//! degradation contract matches disk exactly: any peer error, timeout,
//! down-cooldown, or injected `peer_fetch` fault is a **miss** — the
//! request falls through to a local prefill and never fails. See
//! [`store::PeerFetcher`] (the trait the server's `ClusterPeers`
//! implements) and `server::peers` for the transport.
//!
//! # The codec layer
//!
//! Beneath the tiers sits a pluggable block codec ([`codec`],
//! `--kv-codec {f32,f16,int8}`): every disk-tier block record and
//! every host-tier block past the per-document `--kv-hot-blocks`
//! watermark is stored **encoded** — raw f32 (lossless default), IEEE
//! half precision (~2× smaller), or per-block absmax int8 (~4×
//! smaller, one f32 scale riding inside the payload under the
//! record's checksum). The first `--kv-hot-blocks` blocks of each
//! document stay as raw pooled f32 (content-shared, CoW) so the head
//! of every document assembles at full speed; cold blocks dequantize
//! **on read** ([`codec::KvCodec::decode_span`]) straight into the
//! f32 assembly scratch, so attention/decode consumers never see
//! encoded bytes. Byte budgets across all tiers charge **physical**
//! (encoded) bytes, so `--kv-codec int8` holds ~4× more blocks under
//! the same `--host-cache-mb`/`--disk-cache-mb`. One codec instance
//! per serving stack ([`codec::codec_for`]) is shared by the host
//! pool and the disk tier; its [`codec::CodecStats`] flow through
//! metrics, the `cmd:metrics` wire, and the bench rows.
//!
//! # Eviction + pin contract
//!
//! Eviction (pluggable via [`EvictionPolicy`]: [`LruPolicy`] or
//! [`CostAwarePolicy`], both scoring per candidate **unit** — a block
//! where the tier is block-granular, tail blocks first within one
//! document) only ever removes **unpinned** units. In-flight work
//! pins the document hashes it planned ([`store::PinGuard`], from
//! [`EngineDocCache::pin_planned`] — or individual blocks via
//! [`EngineDocCache::pin_planned_blocks`], where a whole-document pin
//! is the block index [`store::PIN_ALL`]) for as long as the guard
//! lives — sessions pin across prefill→assemble→decode, and the
//! engine batch loop pins a whole batch's planned hashes — so
//! eviction can never race a live assemble. The **host tier** honors
//! every engine's pins (its entries are shared); a **residency tier**
//! honors only its own engine's pins, because evicting another
//! engine's resident copy cannot invalidate `Arc`-held documents and
//! must not be blockable cross-engine. An eviction between pins can
//! therefore only ever cost a disk load or a recompute, never dangle
//! a reference: block payloads are extracted under the host lock
//! before their slots are freed, and assembly reads through
//! refcounted `BlockRef`s. Pins are counted (re-pinning is fine) and
//! may name hashes that are not published yet. The disk tier needs no
//! pins: its files are copies, and live entries are `Arc`-held in
//! RAM.
//!
//! # Fault-tolerance contract
//!
//! The cache hierarchy is an accelerator, so every tier degrades to
//! the tier below it — ultimately to a model prefill — rather than
//! failing a request:
//!
//! * **Disk errors are misses.** A failed read keeps the index entry
//!   (the error may be transient) and reads as a miss; a failed write
//!   only ever loses a future shortcut. `NotFound` is stale-index
//!   cleanup, not an I/O error.
//! * **A circuit breaker guards the device.** `--disk-breaker-
//!   threshold` consecutive I/O errors open it
//!   ([`DiskDocCache::with_breaker`]): while open, reads answer as
//!   misses and writebacks are skipped without touching the failing
//!   device; after `--disk-breaker-probe-ms` one operation probes
//!   half-open — success re-closes, failure re-opens. Threshold 0
//!   disables it.
//! * **Corruption is contained and bounded.** Metadata corruption
//!   quarantines the whole file (preserving its content address for
//!   forensics); a bad block record drops alone. The `quarantine/`
//!   directory is capped ([`DiskDocCache::with_quarantine_cap`],
//!   default [`disk::DEFAULT_QUARANTINE_CAP_BYTES`]) with oldest-first
//!   deletion, so a corrupting device cannot fill the disk twice.
//!
//! All of it is deterministically testable: a
//! [`crate::faultinject::FaultPlan`] attached via
//! [`DiskDocCache::with_faults`] injects read/write errors, added
//! latency, block-payload corruption, and codec decode failure at the
//! exact sites this contract covers, and the `DiskStats` breaker /
//! quarantine counters flow through [`crate::metrics::Metrics`] to the
//! `cmd:metrics` wire and the bench rows.
//!
//! # Stats
//!
//! Each RAM tier keeps its own [`CacheStats`]; `hits`/`misses`/
//! `evictions` (whole-entry removals)/`publishes`/`reinserts`/
//! `hash_collisions`/`peak_bytes` are lifetime counters,
//! `current_bytes` is current state (see [`store`]). The pool keeps
//! [`pool::PoolStats`] — slots total/live/free, slab bytes, grow
//! events, blocks evicted/spilled, share hits, partial evictions —
//! surfaced on the `cmd:metrics` wire as the `pool` object. The disk
//! tier keeps [`DiskStats`] (hits/misses/spills/loads/corrupt/
//! corrupt_blocks/collisions/evictions/bytes) plus a buffer of
//! per-load latencies drained into the metrics histogram.
//!
//! # Concurrency invariants & how to verify them
//!
//! Every lock and condvar in this tree goes through the
//! [`crate::sync`] facade. The lock classes and what each guards:
//!
//! * `pin-map` — one engine's planned-hash pin counts ([`store`]);
//! * `host-inner` — the host tier's entry map, in-flight lease set,
//!   stats, and pins ([`store::HostDocCache`]; the `published`
//!   condvar rides on it);
//! * `kv-blocks` — one document's block-slot list
//!   ([`pool::KvBlocks`], per-instance — siblings are unordered);
//! * `pool-inner` — the slab, refcounts, free list, and content map
//!   ([`pool::KvBlockPool`]);
//! * `residency-board` — one engine's advertised hashes
//!   ([`residency`]);
//! * `disk-index` — the disk tier's index, stats, and circuit
//!   breaker ([`DiskDocCache`]).
//!
//! Canonical acquisition order (hold left, take right — **never**
//! the reverse): `pin-map → host-inner → kv-blocks → pool-inner`,
//! with `host-inner → residency-board` and `disk-index → fault-plan`
//! as side chains. Disk reads, spill writes, and peer fetches all run
//! *outside* `host-inner`: payloads are extracted under the lock and
//! written after release, so a slow device can never wedge lookups.
//!
//! The invariants the tooling checks:
//!
//! * **Exactly-once leasing** — per document hash, at most one
//!   [`store::PrefillLease`] exists at a time; every concurrent
//!   requester is served its publish (or woken to retry on
//!   abandonment), so each unique document is prefilled once
//!   process-wide (cluster-wide under `--peers`).
//! * **Refcount safety** — a pool slot is freed exactly when its
//!   last [`pool::BlockRef`] drops; stray releases are counted in
//!   [`PoolStats::double_frees`] (never a panic, never another
//!   block's corruption); CoW writes move the writer to a private
//!   slot and never mutate a sharer's payload.
//! * **Breaker step reporting** — [`BreakerCore`] reports each
//!   open/close transition exactly once under racing probes, so the
//!   metrics/log edge triggers fire once per transition.
//!
//! How to verify locally:
//!
//! * exhaustive interleavings (loom models of all three invariants):
//!   `RUSTFLAGS="--cfg loom" cargo test --release --test loom_models`
//! * lock-order deadlock detection across the whole suite:
//!   `SAMKV_LOCKCHECK=1 cargo test` (or `--features lockcheck`)
//! * panic-path lint over `server/`+`coordinator/`+`kvcache/`:
//!   `tools/lint` (allowlist ratchet in `rust/lint_allowlist.txt`)
//!
//! [`assembly`] — building the fixed-shape sparse/full buffers the AOT
//! artifacts consume, gathering KV spans straight out of the pool.

// Serving-critical tree: `.unwrap()`/`.expect()` are denied outright
// (the panic-path lint catches the other panic forms); the two
// annotated exceptions justify themselves at the call site and are
// tracked in rust/lint_allowlist.txt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod assembly;
pub mod breaker;
pub mod codec;
pub mod disk;
pub mod evict;
pub mod pool;
pub mod residency;
pub mod store;

pub use assembly::{AssembledContext, BlockRef, SlotKind};
pub use breaker::{BreakerCore, BreakerStep};
pub use codec::{
    codec_by_id, codec_for, CodecSnapshot, CodecStats, KvCodec,
};
pub use disk::{entry_from_bytes, entry_to_bytes, DiskDocCache, DiskStats};
pub use evict::{
    eviction_policy_by_name, CostAwarePolicy, EvictionCandidate,
    EvictionPolicy, LruPolicy, WHOLE_ENTRY,
};
// NOTE: `pool::BlockRef` (the refcounted slot handle) is deliberately
// not re-exported here — `assembly::BlockRef` (a buffer occupancy
// record) already owns the short name; reach the pool handle through
// its module.
pub use pool::{
    KvBlockPool, KvBlocks, KvLayout, PoolStats, DEFAULT_KV_BLOCK_TOKENS,
};
pub use residency::{ResidencyBoard, ResidencyHandle};
pub use store::{
    doc_hash, CacheStats, DocEntry, EngineDocCache, HostDocCache,
    PeerFetcher, PinGuard, TierHit, PIN_ALL,
};
