//! Multi-context KV cache management: the tiered document cache and
//! the buffer assembly that consumes it.
//!
//! # The three tiers
//!
//! Document KV caches (the "multiple-context KV Cache" of the paper:
//! each document prefilled independently at local positions) live in a
//! three-tier storage hierarchy — residency → host → disk — so that
//! one engine's prefill is every engine's hit, and one *process's*
//! prefill survives restarts:
//!
//! ```text
//!   engine 0 thread            engine 1 thread         router
//! ┌───────────────────┐     ┌───────────────────┐   placement reads
//! │ EngineDocCache    │     │ EngineDocCache    │   ResidencyBoard
//! │ (residency tier:  │     │ (residency tier:  │◄──────────────────
//! │  device-resident  │     │  own budget, LRU/ │
//! │  subset, own      │     │  cost-aware)      │
//! │  budget)          │     │                   │
//! └─────────┬─────────┘     └─────────┬─────────┘
//!     miss  │  publish          miss  │  publish
//!           ▼                         ▼
//! ┌─────────────────────────────────────────────────┐
//! │ HostDocCache (shared host tier, Arc<DocEntry>)  │
//! │  content-addressed · thread-safe · byte budget  │
//! │  pin guards · prefill leases (exactly-once)     │
//! └───────────────────────┬─────────────────────────┘
//!        miss (in-lease)  │  spill on evict / write-through
//!                         ▼
//! ┌─────────────────────────────────────────────────┐
//! │ DiskDocCache (persistent tier, --disk-cache-dir)│
//! │  per-hash files · versioned+checksummed format  │
//! │  own byte budget/eviction · quarantine on error │
//! └─────────────────────────────────────────────────┘
//! ```
//!
//! A [`EngineDocCache::get_or_prefill`] miss consults the shared
//! [`HostDocCache`] before running `model.prefill_doc`; a true miss
//! takes a [`store::PrefillLease`] (concurrent requests for the same
//! document block until it publishes — each unique document is
//! prefilled **exactly once process-wide**), consults the persistent
//! [`DiskDocCache`] under that lease when one is attached, and only
//! prefills when the disk misses too — a restarted server or a cold
//! engine serves a previously-seen document with **zero** model
//! prefills. Fresh entries are published back to the host tier either
//! way. Engines advertise their resident hashes on a
//! [`ResidencyBoard`] so the router can prefer the engine that already
//! holds a request's documents, and the engine admission thread
//! prefetches a wave's planned hashes from disk
//! ([`EngineDocCache::prefetch_from_disk`]) while decode keeps
//! running, so disk latency overlaps compute.
//!
//! # Writeback modes
//!
//! Host-tier eviction **spills** instead of dropping
//! ([`crate::config::DiskWriteback`], `--disk-writeback`): `evict`
//! writes victims as they leave RAM; `through` persists every host
//! insert immediately (evictions then find their file already
//! written — content addressing makes the overlap one write total);
//! `off` never writes but still reads, so a pre-seeded directory can
//! warm-start a read-only replica. Disk writes run outside the host
//! lock and a failed write is only ever a lost future shortcut, never
//! a correctness problem.
//!
//! # Corruption / quarantine contract
//!
//! The disk tier never trusts what it reads back: version, filename
//! hash, checksum, geometry, and the stored token ids are all
//! validated, and a file failing any check is quarantined (moved out
//! of the content-addressed namespace) and served as a miss — the
//! request falls back to a model prefill and succeeds. See [`disk`].
//!
//! # Pin-guard contract
//!
//! Eviction (pluggable via [`EvictionPolicy`]: [`LruPolicy`] or
//! [`CostAwarePolicy`]) only ever removes **unpinned** entries.
//! In-flight work pins the document hashes it planned
//! ([`store::PinGuard`], from [`EngineDocCache::pin_planned`]) for as
//! long as the guard lives — sessions pin across
//! prefill→assemble→decode, and the engine batch loop pins a whole
//! batch's planned hashes — so eviction can never race a live
//! assemble. The **host tier** honors every engine's pins (its
//! entries are shared); a **residency tier** honors only its own
//! engine's pins, because evicting another engine's resident copy
//! cannot invalidate `Arc`-held documents and must not be blockable
//! cross-engine. An eviction between pins can therefore only ever
//! cost a disk load or a recompute, never dangle a reference. Pins
//! are counted (re-pinning is fine) and may name hashes that are not
//! published yet. The disk tier needs no pins: its files are copies,
//! and live entries are `Arc`-held in RAM.
//!
//! # Stats
//!
//! Each RAM tier keeps its own [`CacheStats`]; `hits`/`misses`/
//! `evictions`/`publishes`/`reinserts`/`hash_collisions`/`peak_bytes`
//! are lifetime counters, `current_bytes` is current state (see
//! [`store`]). The disk tier keeps [`DiskStats`] (hits/misses/spills/
//! loads/corrupt/collisions/evictions/bytes) plus a buffer of
//! per-load latencies drained into the metrics histogram.
//!
//! [`assembly`] — building the fixed-shape sparse/full buffers the AOT
//! artifacts consume from a set of selected (doc, block) slots.

pub mod assembly;
pub mod disk;
pub mod evict;
pub mod residency;
pub mod store;

pub use assembly::{AssembledContext, BlockRef, SlotKind};
pub use disk::{DiskDocCache, DiskStats};
pub use evict::{
    eviction_policy_by_name, CostAwarePolicy, EvictionCandidate,
    EvictionPolicy, LruPolicy,
};
pub use residency::{ResidencyBoard, ResidencyHandle};
pub use store::{
    doc_hash, CacheStats, DocEntry, EngineDocCache, HostDocCache,
    PinGuard, TierHit,
};
