//! Deterministic RNG substrate (SplitMix64) — no `rand` crate offline.
//!
//! Everything that needs randomness (workload generation, property tests,
//! jittered arrivals) takes an explicit seed so runs are reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-reduced; n > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = { let mut r = Rng::new(7); (0..5).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = Rng::new(7); (0..5).map(|_| r.next_u64()).collect() };
        let c: Vec<u64> = { let mut r = Rng::new(8); (0..5).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(3);
        let mut xs = r.choose_distinct(50, 20);
        xs.sort_unstable();
        xs.dedup();
        assert_eq!(xs.len(), 20);
        assert!(xs.iter().all(|&x| x < 50));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        assert!((s / n as f64 - 0.25).abs() < 0.02);
    }
}
