//! Serving metrics: latency histograms, throughput counters, memory
//! gauges. Thread-safe; the server and coordinator share one registry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Value;
use crate::kvcache::{CacheStats, CodecSnapshot, DiskStats, PoolStats};

/// Log-bucketed latency histogram (microsecond granularity, buckets
/// doubling from 100us to ~400s).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

const N_BUCKETS: usize = 23;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_of(us: u64) -> usize {
        // bucket i covers [100 * 2^i, 100 * 2^(i+1)) microseconds
        let mut b = 0usize;
        let mut edge = 100u64;
        while us >= edge * 2 && b + 1 < N_BUCKETS {
            edge *= 2;
            b += 1;
        }
        b
    }

    pub fn observe_ms(&self, ms: f64) {
        let us = (ms * 1e3).max(0.0) as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e3
        }
    }

    /// Approximate percentile from bucket upper edges.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0u64;
        let mut edge = 100u64;
        for b in &self.buckets {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return edge as f64 * 2.0 / 1e3; // bucket upper edge, ms
            }
            edge *= 2;
        }
        edge as f64 / 1e3
    }
}

/// Registry shared across the serving stack.
#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft: Histogram,
    pub e2e: Histogram,
    pub decode: Histogram,
    /// Pure planning stage (staged serving protocol).
    pub plan: Histogram,
    /// Document-prefill stage (per request, dedup shares included).
    pub doc_prefill: Histogram,
    /// Queue wait: submit → plan start (observed at admission, so
    /// requests that later fail still count).
    pub queue_wait: Histogram,
    /// Sessions currently in engine decode pools, summed over engines
    /// (gauge: engines add on admission, subtract on completion).
    pub active_sessions: AtomicU64,
    /// Fused decode rounds dispatched (one `Model::decode_batch` call
    /// per round per engine).
    pub fused_rounds: AtomicU64,
    /// Sessions covered by those fused rounds; `fused_round_sessions /
    /// fused_rounds` is the mean decode batch size actually achieved.
    pub fused_round_sessions: AtomicU64,
    /// Rounds whose dispatch went through the lane-padded batched
    /// decode entries (a single XLA execution per same-buffer chunk).
    pub batched_rounds: AtomicU64,
    /// Runtime executions issued by fused rounds; `round_executions /
    /// fused_rounds` is the executions-per-round the batched entries
    /// exist to drive to 1.
    pub round_executions: AtomicU64,
    /// Live lanes dispatched through the batched entries, and the
    /// total (live + padding) lane capacity of those executions —
    /// their ratio is the lane occupancy.
    pub lanes_live: AtomicU64,
    pub lanes_total: AtomicU64,
    /// Admission (plan/prefill/assemble/attend) wall time that ran on
    /// the helper thread while the decode pool was busy — the overlap
    /// the staged-admission split buys (microseconds).
    pub assemble_overlap_us: AtomicU64,
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub kv_bytes_gauge: AtomicU64,
    /// Document prefills executed by the engine's batch-dedup stage
    /// (requests sharing a document count it once; per-session cache
    /// hits never count).
    pub doc_prefills: AtomicU64,
    /// Shared host document-cache tier: monotone totals snapshotted
    /// after every served batch and folded in with `fetch_max`, so a
    /// stale snapshot from a racing engine can never regress them
    /// (the tier is process-wide; every engine reads the same totals).
    pub host_hits: AtomicU64,
    pub host_misses: AtomicU64,
    pub host_publishes: AtomicU64,
    pub host_evictions: AtomicU64,
    pub host_bytes: AtomicU64,
    /// Host-tier content-hash collisions (by-hash hits whose stored
    /// token ids did not match — served as misses, never as another
    /// document's KV).
    pub host_collisions: AtomicU64,
    /// Per-engine residency tiers, accumulated as per-batch deltas
    /// summed across all engines.
    pub resident_hits: AtomicU64,
    pub resident_misses: AtomicU64,
    pub resident_evictions: AtomicU64,
    /// Persistent disk tier beneath the host tier: process-wide
    /// monotone totals folded in with `fetch_max` like the host tier
    /// (`disk_bytes` is a gauge of the directory's current footprint).
    pub disk_hits: AtomicU64,
    pub disk_misses: AtomicU64,
    pub disk_spills: AtomicU64,
    pub disk_loads: AtomicU64,
    pub disk_corrupt: AtomicU64,
    /// Individual block records dropped by their per-block checksum
    /// (block-list disk format; the rest of the file still served).
    pub disk_corrupt_blocks: AtomicU64,
    pub disk_collisions: AtomicU64,
    pub disk_evictions: AtomicU64,
    pub disk_bytes: AtomicU64,
    /// Total file bytes read back by disk loads (monotone; smaller
    /// codecs shrink it proportionally).
    pub disk_bytes_loaded: AtomicU64,
    /// Disk-tier load latency (file read + decode + checksum) per
    /// successful load.
    pub disk_load: Histogram,
    /// KV codec layer (`--kv-codec`, see `kvcache::codec`): monotone
    /// process-wide totals folded in with `fetch_max` like the host
    /// tier. `codec_logical_bytes` / `codec_physical_bytes` is the
    /// achieved compression ratio.
    pub codec_blocks_encoded: AtomicU64,
    pub codec_blocks_decoded: AtomicU64,
    pub codec_logical_bytes: AtomicU64,
    pub codec_physical_bytes: AtomicU64,
    /// Per-block dequantization latency on the read path.
    pub codec_decode: Histogram,
    /// Name of the active codec (`f32`/`f16`/`int8`), set by the first
    /// [`Self::record_codec`] flush.
    codec_name: Mutex<String>,
    /// Paged KV block pool (process-wide slab under the RAM tiers):
    /// slot/slab occupancy are gauges (last snapshot wins), the event
    /// counters are monotone totals folded in with `fetch_max` like
    /// the host tier.
    pub pool_slots_total: AtomicU64,
    pub pool_slots_live: AtomicU64,
    pub pool_slots_free: AtomicU64,
    pub pool_slab_bytes: AtomicU64,
    pub pool_grow_events: AtomicU64,
    pub pool_blocks_evicted: AtomicU64,
    pub pool_blocks_spilled: AtomicU64,
    pub pool_share_hits: AtomicU64,
    pub pool_partial_evictions: AtomicU64,
    pub pool_double_frees: AtomicU64,
    /// Fault injection (`--fault-plan`, see [`crate::faultinject`]):
    /// total injections plus one counter per site, folded in with
    /// `fetch_max` from the plan's own monotone counters (the plan is
    /// process-wide, so any engine's flush carries the same totals).
    pub faults_injected: AtomicU64,
    pub faults_disk_read: AtomicU64,
    pub faults_disk_write: AtomicU64,
    pub faults_disk_latency: AtomicU64,
    pub faults_corrupt_block: AtomicU64,
    pub faults_codec_decode: AtomicU64,
    pub faults_doc_prefill: AtomicU64,
    pub faults_engine_kill: AtomicU64,
    pub faults_peer_fetch: AtomicU64,
    /// Self-healing serving: requests resubmitted to a surviving
    /// engine after a delivery failure, and how many of those retries
    /// ultimately produced an answer (direct event counts).
    pub retries: AtomicU64,
    pub retry_successes: AtomicU64,
    /// Requests failed with a structured timeout error because their
    /// `--request-timeout-ms` deadline passed (queue, plan/prefill, or
    /// decode — wherever the sweep caught them).
    pub timeouts: AtomicU64,
    /// Times the router newly marked an engine down (an engine can
    /// contribute more than once if it is marked up again).
    pub engine_down_events: AtomicU64,
    /// Engines currently marked down (gauge: router snapshot).
    pub engines_down: AtomicU64,
    /// Disk-tier I/O fault handling (see `kvcache::disk`): error and
    /// circuit-breaker transition totals are monotone (`fetch_max`);
    /// `disk_breaker_open` and `disk_quarantined_bytes` are gauges.
    pub disk_io_errors: AtomicU64,
    pub disk_breaker_opens: AtomicU64,
    pub disk_breaker_closes: AtomicU64,
    pub disk_breaker_short_circuits: AtomicU64,
    pub disk_breaker_open: AtomicU64,
    pub disk_quarantined_bytes: AtomicU64,
    pub disk_quarantine_drops: AtomicU64,
    /// Multi-node peer tier (`--peers`, see `server::peers`): direct
    /// event counts — each node counts only its own outbound fetches
    /// (`peer_fetch_hits`/`peer_fetch_misses`/`peer_bytes_in`) and the
    /// entry bytes it served to others (`peer_bytes_out`); `peers_down`
    /// is a gauge of peers currently in down-cooldown.
    pub peer_fetch_hits: AtomicU64,
    pub peer_fetch_misses: AtomicU64,
    pub peer_bytes_in: AtomicU64,
    pub peer_bytes_out: AtomicU64,
    pub peers_down: AtomicU64,
    /// Peer fetch latency (dial + transfer) per successful fetch.
    pub peer_fetch: Histogram,
    started: Mutex<Option<Instant>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        *m.started.lock().unwrap() = Some(Instant::now());
        m
    }

    pub fn record_completion(&self, ttft_ms: f64, decode_ms: f64,
                             tokens: usize, kv_bytes: usize) {
        self.ttft.observe_ms(ttft_ms);
        self.decode.observe_ms(decode_ms);
        self.e2e.observe_ms(ttft_ms + decode_ms);
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated
            .fetch_add(tokens as u64, Ordering::Relaxed);
        self.kv_bytes_gauge
            .store(kv_bytes as u64, Ordering::Relaxed);
    }

    /// Record the staged-protocol timings of one completed request.
    pub fn record_stage_times(&self, plan_ms: f64, doc_prefill_ms: f64) {
        self.plan.observe_ms(plan_ms);
        self.doc_prefill.observe_ms(doc_prefill_ms);
    }

    /// Record one fused decode round's dispatch accounting (see
    /// `model::DecodeRound`): how many sessions it covered, how many
    /// runtime executions it cost, and — when the lane-padded batched
    /// entries ran — the live/total lane split.
    pub fn record_decode_round(&self, sessions: u64, executions: u64,
                               lanes_live: u64, lanes_total: u64) {
        self.fused_rounds.fetch_add(1, Ordering::Relaxed);
        self.fused_round_sessions
            .fetch_add(sessions, Ordering::Relaxed);
        self.round_executions
            .fetch_add(executions, Ordering::Relaxed);
        if lanes_total > 0 {
            self.batched_rounds.fetch_add(1, Ordering::Relaxed);
            self.lanes_live.fetch_add(lanes_live, Ordering::Relaxed);
            self.lanes_total.fetch_add(lanes_total, Ordering::Relaxed);
        }
    }

    /// Record admission work that overlapped in-flight decode rounds.
    pub fn record_assemble_overlap(&self, ms: f64) {
        self.assemble_overlap_us
            .fetch_add((ms * 1e3).max(0.0) as u64, Ordering::Relaxed);
    }

    /// Mean runtime executions per fused decode round (1.0 = every
    /// round was a single XLA execution).
    pub fn executions_per_round(&self) -> f64 {
        let rounds = self.fused_rounds.load(Ordering::Relaxed);
        if rounds == 0 {
            0.0
        } else {
            self.round_executions.load(Ordering::Relaxed) as f64
                / rounds as f64
        }
    }

    /// Live fraction of the batched entries' lane capacity (0 when no
    /// batched execution ran).
    pub fn lane_occupancy(&self) -> f64 {
        let total = self.lanes_total.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.lanes_live.load(Ordering::Relaxed) as f64 / total as f64
        }
    }

    /// Total admission time overlapped with decode, in ms.
    pub fn assemble_overlap_ms(&self) -> f64 {
        self.assemble_overlap_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Flush document-cache tier counters after a served batch: the
    /// shared host tier's counters are monotone totals, folded in with
    /// `fetch_max` so concurrent engine flushes can never regress them
    /// with a stale snapshot (`host_bytes` is a gauge: last write
    /// wins); the engine's residency-tier `delta` (since its previous
    /// flush) is added.
    pub fn record_cache_tiers(&self, host: &CacheStats,
                              resident_delta: &CacheStats) {
        self.host_hits.fetch_max(host.hits, Ordering::Relaxed);
        self.host_misses.fetch_max(host.misses, Ordering::Relaxed);
        self.host_publishes
            .fetch_max(host.publishes, Ordering::Relaxed);
        self.host_evictions
            .fetch_max(host.evictions, Ordering::Relaxed);
        self.host_collisions
            .fetch_max(host.hash_collisions, Ordering::Relaxed);
        self.host_bytes
            .store(host.current_bytes as u64, Ordering::Relaxed);
        self.resident_hits
            .fetch_add(resident_delta.hits, Ordering::Relaxed);
        self.resident_misses
            .fetch_add(resident_delta.misses, Ordering::Relaxed);
        self.resident_evictions
            .fetch_add(resident_delta.evictions, Ordering::Relaxed);
    }

    /// Flush the persistent disk tier's counters (monotone process-wide
    /// totals, `fetch_max` like the host tier; bytes is a gauge) and
    /// fold the load-latency samples drained from
    /// [`crate::kvcache::DiskDocCache::take_load_samples`] into the
    /// load histogram. The engine calls this after every admission
    /// wave, beside [`Self::record_cache_tiers`].
    pub fn record_disk_tier(&self, disk: &DiskStats, load_ms: &[f64]) {
        self.disk_hits.fetch_max(disk.hits, Ordering::Relaxed);
        self.disk_misses.fetch_max(disk.misses, Ordering::Relaxed);
        self.disk_spills.fetch_max(disk.spills, Ordering::Relaxed);
        self.disk_loads.fetch_max(disk.loads, Ordering::Relaxed);
        self.disk_corrupt.fetch_max(disk.corrupt, Ordering::Relaxed);
        self.disk_corrupt_blocks
            .fetch_max(disk.corrupt_blocks, Ordering::Relaxed);
        self.disk_collisions
            .fetch_max(disk.collisions, Ordering::Relaxed);
        self.disk_evictions
            .fetch_max(disk.evictions, Ordering::Relaxed);
        self.disk_bytes
            .store(disk.current_bytes as u64, Ordering::Relaxed);
        self.disk_bytes_loaded
            .fetch_max(disk.bytes_loaded, Ordering::Relaxed);
        self.disk_io_errors
            .fetch_max(disk.io_errors, Ordering::Relaxed);
        self.disk_breaker_opens
            .fetch_max(disk.breaker_opens, Ordering::Relaxed);
        self.disk_breaker_closes
            .fetch_max(disk.breaker_closes, Ordering::Relaxed);
        self.disk_breaker_short_circuits
            .fetch_max(disk.breaker_short_circuits, Ordering::Relaxed);
        self.disk_breaker_open
            .store(disk.breaker_open, Ordering::Relaxed);
        self.disk_quarantined_bytes
            .store(disk.quarantined_bytes, Ordering::Relaxed);
        self.disk_quarantine_drops
            .fetch_max(disk.quarantine_drops, Ordering::Relaxed);
        for &ms in load_ms {
            self.disk_load.observe_ms(ms);
        }
    }

    /// Flush the fault-injection plan's per-site injection counters
    /// (monotone process-wide totals on the shared plan, folded in
    /// with `fetch_max`). The engine calls this after every admission
    /// wave when a `--fault-plan` is active.
    pub fn record_faults(&self, plan: &crate::faultinject::FaultPlan) {
        self.faults_injected
            .fetch_max(plan.total_injected(), Ordering::Relaxed);
        for (site, n) in plan.counts() {
            let counter = match site {
                "disk_read" => &self.faults_disk_read,
                "disk_write" => &self.faults_disk_write,
                "disk_latency" => &self.faults_disk_latency,
                "corrupt_block" => &self.faults_corrupt_block,
                "codec_decode" => &self.faults_codec_decode,
                "doc_prefill" => &self.faults_doc_prefill,
                "engine_kill" => &self.faults_engine_kill,
                "peer_fetch" => &self.faults_peer_fetch,
                _ => continue,
            };
            counter.fetch_max(n, Ordering::Relaxed);
        }
    }

    /// Fault-injection and self-healing counters as a JSON object
    /// (`cmd:metrics` wire, bench artifacts): per-site injection
    /// totals, retry/timeout accounting, engine supervision, and the
    /// disk circuit breaker's state machine.
    pub fn faults_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("injected", g(&self.faults_injected))
            .set("disk_read", g(&self.faults_disk_read))
            .set("disk_write", g(&self.faults_disk_write))
            .set("disk_latency", g(&self.faults_disk_latency))
            .set("corrupt_block", g(&self.faults_corrupt_block))
            .set("codec_decode", g(&self.faults_codec_decode))
            .set("doc_prefill", g(&self.faults_doc_prefill))
            .set("engine_kill", g(&self.faults_engine_kill))
            .set("peer_fetch", g(&self.faults_peer_fetch))
            .set("retries", g(&self.retries))
            .set("retry_successes", g(&self.retry_successes))
            .set("timeouts", g(&self.timeouts))
            .set("engine_down_events", g(&self.engine_down_events))
            .set("engines_down", g(&self.engines_down))
            .set("disk_io_errors", g(&self.disk_io_errors))
            .set("disk_breaker_opens", g(&self.disk_breaker_opens))
            .set("disk_breaker_closes", g(&self.disk_breaker_closes))
            .set("disk_breaker_short_circuits",
                 g(&self.disk_breaker_short_circuits))
            .set("disk_breaker_open", g(&self.disk_breaker_open))
            .set("disk_quarantined_bytes",
                 g(&self.disk_quarantined_bytes))
            .set("disk_quarantine_drops",
                 g(&self.disk_quarantine_drops))
    }

    /// Flush the KV codec layer's counters (one codec instance per
    /// serving stack, shared by the host pool and the disk tier, so
    /// any engine's snapshot carries the same monotone totals —
    /// `fetch_max` like the host tier) and fold the decode-latency
    /// samples drained from
    /// [`crate::kvcache::CodecStats::take_decode_samples`] into the
    /// decode histogram. The engine calls this after every admission
    /// wave, beside [`Self::record_pool`].
    pub fn record_codec(&self, snap: &CodecSnapshot,
                        decode_ms: &[f64]) {
        self.codec_blocks_encoded
            .fetch_max(snap.blocks_encoded, Ordering::Relaxed);
        self.codec_blocks_decoded
            .fetch_max(snap.blocks_decoded, Ordering::Relaxed);
        self.codec_logical_bytes
            .fetch_max(snap.logical_bytes, Ordering::Relaxed);
        self.codec_physical_bytes
            .fetch_max(snap.physical_bytes, Ordering::Relaxed);
        let mut name = self.codec_name.lock().unwrap();
        if *name != snap.codec {
            *name = snap.codec.to_string();
        }
        drop(name);
        for &ms in decode_ms {
            self.codec_decode.observe_ms(ms);
        }
    }

    /// Logical / physical bytes across every block the codec encoded
    /// (1.0 when nothing was encoded — or under the lossless f32
    /// codec, which stores blocks raw).
    pub fn codec_compression_ratio(&self) -> f64 {
        let phys = self.codec_physical_bytes.load(Ordering::Relaxed);
        if phys == 0 {
            1.0
        } else {
            self.codec_logical_bytes.load(Ordering::Relaxed) as f64
                / phys as f64
        }
    }

    /// The codec layer's counters as a JSON object (the `codec` object
    /// on the `cmd:metrics` wire and in bench artifacts).
    pub fn codec_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("codec", self.codec_name.lock().unwrap().as_str())
            .set("blocks_encoded", g(&self.codec_blocks_encoded))
            .set("blocks_decoded", g(&self.codec_blocks_decoded))
            .set("logical_bytes", g(&self.codec_logical_bytes))
            .set("physical_bytes", g(&self.codec_physical_bytes))
            .set("compression_ratio", self.codec_compression_ratio())
            .set("decode_mean_ms", self.codec_decode.mean_ms())
            .set("decode_p50_ms", self.codec_decode.percentile_ms(0.50))
            .set("decode_p95_ms", self.codec_decode.percentile_ms(0.95))
    }

    /// Flush the block pool's counters (one process-wide pool; any
    /// engine's snapshot carries the same totals): occupancy gauges
    /// store, event totals fold in with `fetch_max` so a stale
    /// snapshot can never regress them. The engine calls this after
    /// every admission wave, beside [`Self::record_cache_tiers`].
    pub fn record_pool(&self, pool: &PoolStats) {
        self.pool_slots_total
            .store(pool.slots_total, Ordering::Relaxed);
        self.pool_slots_live.store(pool.slots_live, Ordering::Relaxed);
        self.pool_slots_free.store(pool.slots_free, Ordering::Relaxed);
        self.pool_slab_bytes.store(pool.slab_bytes, Ordering::Relaxed);
        self.pool_grow_events
            .fetch_max(pool.grow_events, Ordering::Relaxed);
        self.pool_blocks_evicted
            .fetch_max(pool.blocks_evicted, Ordering::Relaxed);
        self.pool_blocks_spilled
            .fetch_max(pool.blocks_spilled, Ordering::Relaxed);
        self.pool_share_hits
            .fetch_max(pool.share_hits, Ordering::Relaxed);
        self.pool_partial_evictions
            .fetch_max(pool.partial_evictions, Ordering::Relaxed);
        self.pool_double_frees
            .fetch_max(pool.double_frees, Ordering::Relaxed);
    }

    /// The block pool's counters as a JSON object (the `pool` object
    /// on the `cmd:metrics` wire and in bench artifacts).
    pub fn pool_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("slots_total", g(&self.pool_slots_total))
            .set("slots_live", g(&self.pool_slots_live))
            .set("slots_free", g(&self.pool_slots_free))
            .set("slab_bytes", g(&self.pool_slab_bytes))
            .set("grow_events", g(&self.pool_grow_events))
            .set("blocks_evicted", g(&self.pool_blocks_evicted))
            .set("blocks_spilled", g(&self.pool_blocks_spilled))
            .set("share_hits", g(&self.pool_share_hits))
            .set("partial_evictions", g(&self.pool_partial_evictions))
            .set("double_frees", g(&self.pool_double_frees))
    }

    /// Scheduler-facing serving snapshot as a JSON object (server wire
    /// stats, bench artifacts): latency percentiles, queue wait, and
    /// the continuous-batching gauges.
    pub fn serving_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("active_sessions", g(&self.active_sessions))
            .set("queue_wait_mean_ms", self.queue_wait.mean_ms())
            .set("queue_wait_p50_ms", self.queue_wait.percentile_ms(0.50))
            .set("queue_wait_p95_ms", self.queue_wait.percentile_ms(0.95))
            .set("ttft_p50_ms", self.ttft.percentile_ms(0.50))
            .set("ttft_p95_ms", self.ttft.percentile_ms(0.95))
            .set("e2e_p50_ms", self.e2e.percentile_ms(0.50))
            .set("e2e_p95_ms", self.e2e.percentile_ms(0.95))
            .set("fused_rounds", g(&self.fused_rounds))
            .set("fused_round_sessions", g(&self.fused_round_sessions))
            .set("batched_rounds", g(&self.batched_rounds))
            .set("round_executions", g(&self.round_executions))
            .set("executions_per_round", self.executions_per_round())
            .set("lane_occupancy", self.lane_occupancy())
            .set("assemble_overlap_ms", self.assemble_overlap_ms())
    }

    /// Per-tier cache counters as a JSON object (server wire stats,
    /// bench artifacts): `host`, `resident`, and the persistent `disk`
    /// tier (counters + load-latency mean/p50/p95).
    pub fn cache_tiers_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("host",
                 Value::obj()
                     .set("hits", g(&self.host_hits))
                     .set("misses", g(&self.host_misses))
                     .set("publishes", g(&self.host_publishes))
                     .set("evictions", g(&self.host_evictions))
                     .set("collisions", g(&self.host_collisions))
                     .set("bytes", g(&self.host_bytes)))
            .set("resident",
                 Value::obj()
                     .set("hits", g(&self.resident_hits))
                     .set("misses", g(&self.resident_misses))
                     .set("evictions", g(&self.resident_evictions)))
            .set("disk",
                 Value::obj()
                     .set("hits", g(&self.disk_hits))
                     .set("misses", g(&self.disk_misses))
                     .set("spills", g(&self.disk_spills))
                     .set("loads", g(&self.disk_loads))
                     .set("corrupt", g(&self.disk_corrupt))
                     .set("corrupt_blocks", g(&self.disk_corrupt_blocks))
                     .set("collisions", g(&self.disk_collisions))
                     .set("evictions", g(&self.disk_evictions))
                     .set("bytes", g(&self.disk_bytes))
                     .set("bytes_loaded", g(&self.disk_bytes_loaded))
                     .set("load_mean_ms", self.disk_load.mean_ms())
                     .set("load_p50_ms", self.disk_load.percentile_ms(0.50))
                     .set("load_p95_ms",
                          self.disk_load.percentile_ms(0.95)))
    }

    /// The multi-node peer tier's counters as a JSON object (the
    /// `peers` object on the `cmd:metrics` wire and in bench
    /// artifacts). All zeros on a single-node stack — the object is
    /// always present so wire consumers need no feature probing.
    pub fn peers_json(&self) -> Value {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as i64;
        Value::obj()
            .set("fetch_hits", g(&self.peer_fetch_hits))
            .set("fetch_misses", g(&self.peer_fetch_misses))
            .set("bytes_in", g(&self.peer_bytes_in))
            .set("bytes_out", g(&self.peer_bytes_out))
            .set("down", g(&self.peers_down))
            .set("fetch_mean_ms", self.peer_fetch.mean_ms())
            .set("fetch_p50_ms", self.peer_fetch.percentile_ms(0.50))
            .set("fetch_p95_ms", self.peer_fetch.percentile_ms(0.95))
    }

    pub fn uptime_s(&self) -> f64 {
        self.started
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Completed requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        let up = self.uptime_s();
        if up <= 0.0 {
            0.0
        } else {
            self.completed.load(Ordering::Relaxed) as f64 / up
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} completed={} rejected={} tokens={} \
             doc_prefills={} \
             ttft(mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms) \
             plan(mean={:.2}ms) doc_prefill(mean={:.1}ms) \
             queue_wait(mean={:.1}ms p95={:.1}ms) active={} \
             fused(rounds={} sessions={}) \
             batched(rounds={} execs/round={:.2} occupancy={:.2}) \
             assemble_overlap={:.1}ms \
             e2e(mean={:.1}ms p95={:.1}ms) throughput={:.2}req/s \
             host(hits={} misses={} publishes={} evictions={} bytes={}) \
             resident(hits={} misses={} evictions={}) \
             disk(hits={} misses={} spills={} loads={} corrupt={} \
             bytes={} loaded={} load_mean={:.1}ms) \
             pool(slots={}/{} free={} slab_bytes={} grows={} \
             evicted={} spilled={} shares={} partial={}) \
             codec({} encoded={} decoded={} ratio={:.2} \
             decode_mean={:.3}ms) \
             peers(hits={} misses={} in={} out={} down={} \
             fetch_mean={:.1}ms) \
             faults(injected={} retries={} retry_ok={} timeouts={} \
             engine_down={} down_now={}) \
             breaker(open={} opens={} closes={} short_circuits={} \
             io_errors={} quarantined_bytes={} quarantine_drops={})",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.doc_prefills.load(Ordering::Relaxed),
            self.ttft.mean_ms(),
            self.ttft.percentile_ms(0.50),
            self.ttft.percentile_ms(0.95),
            self.ttft.percentile_ms(0.99),
            self.plan.mean_ms(),
            self.doc_prefill.mean_ms(),
            self.queue_wait.mean_ms(),
            self.queue_wait.percentile_ms(0.95),
            self.active_sessions.load(Ordering::Relaxed),
            self.fused_rounds.load(Ordering::Relaxed),
            self.fused_round_sessions.load(Ordering::Relaxed),
            self.batched_rounds.load(Ordering::Relaxed),
            self.executions_per_round(),
            self.lane_occupancy(),
            self.assemble_overlap_ms(),
            self.e2e.mean_ms(),
            self.e2e.percentile_ms(0.95),
            self.throughput_rps(),
            self.host_hits.load(Ordering::Relaxed),
            self.host_misses.load(Ordering::Relaxed),
            self.host_publishes.load(Ordering::Relaxed),
            self.host_evictions.load(Ordering::Relaxed),
            self.host_bytes.load(Ordering::Relaxed),
            self.resident_hits.load(Ordering::Relaxed),
            self.resident_misses.load(Ordering::Relaxed),
            self.resident_evictions.load(Ordering::Relaxed),
            self.disk_hits.load(Ordering::Relaxed),
            self.disk_misses.load(Ordering::Relaxed),
            self.disk_spills.load(Ordering::Relaxed),
            self.disk_loads.load(Ordering::Relaxed),
            self.disk_corrupt.load(Ordering::Relaxed),
            self.disk_bytes.load(Ordering::Relaxed),
            self.disk_bytes_loaded.load(Ordering::Relaxed),
            self.disk_load.mean_ms(),
            self.pool_slots_live.load(Ordering::Relaxed),
            self.pool_slots_total.load(Ordering::Relaxed),
            self.pool_slots_free.load(Ordering::Relaxed),
            self.pool_slab_bytes.load(Ordering::Relaxed),
            self.pool_grow_events.load(Ordering::Relaxed),
            self.pool_blocks_evicted.load(Ordering::Relaxed),
            self.pool_blocks_spilled.load(Ordering::Relaxed),
            self.pool_share_hits.load(Ordering::Relaxed),
            self.pool_partial_evictions.load(Ordering::Relaxed),
            {
                let name = self.codec_name.lock().unwrap();
                if name.is_empty() {
                    "f32".to_string()
                } else {
                    name.clone()
                }
            },
            self.codec_blocks_encoded.load(Ordering::Relaxed),
            self.codec_blocks_decoded.load(Ordering::Relaxed),
            self.codec_compression_ratio(),
            self.codec_decode.mean_ms(),
            self.peer_fetch_hits.load(Ordering::Relaxed),
            self.peer_fetch_misses.load(Ordering::Relaxed),
            self.peer_bytes_in.load(Ordering::Relaxed),
            self.peer_bytes_out.load(Ordering::Relaxed),
            self.peers_down.load(Ordering::Relaxed),
            self.peer_fetch.mean_ms(),
            self.faults_injected.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.retry_successes.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.engine_down_events.load(Ordering::Relaxed),
            self.engines_down.load(Ordering::Relaxed),
            self.disk_breaker_open.load(Ordering::Relaxed),
            self.disk_breaker_opens.load(Ordering::Relaxed),
            self.disk_breaker_closes.load(Ordering::Relaxed),
            self.disk_breaker_short_circuits.load(Ordering::Relaxed),
            self.disk_io_errors.load(Ordering::Relaxed),
            self.disk_quarantined_bytes.load(Ordering::Relaxed),
            self.disk_quarantine_drops.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::default();
        for ms in [1.0, 2.0, 3.0] {
            h.observe_ms(ms);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ms() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let h = Histogram::default();
        for i in 0..1000 {
            h.observe_ms(i as f64 / 10.0);
        }
        let p50 = h.percentile_ms(0.50);
        let p95 = h.percentile_ms(0.95);
        let p99 = h.percentile_ms(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 > 10.0 && p99 <= 400.0);
    }

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.record_completion(10.0, 5.0, 3, 1024);
        m.record_completion(20.0, 5.0, 2, 2048);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 5);
        assert!((m.ttft.mean_ms() - 15.0).abs() < 0.1);
        assert!(m.report().contains("completed=2"));
    }

    #[test]
    fn cache_tier_counters_flush() {
        let m = Metrics::new();
        let host = CacheStats {
            hits: 5,
            misses: 2,
            publishes: 2,
            evictions: 1,
            current_bytes: 640,
            ..CacheStats::default()
        };
        let delta =
            CacheStats { hits: 3, misses: 1, ..CacheStats::default() };
        m.record_cache_tiers(&host, &delta);
        m.record_cache_tiers(&host, &delta);
        // host tier is an absolute snapshot; residency deltas accumulate
        assert_eq!(m.host_hits.load(Ordering::Relaxed), 5);
        assert_eq!(m.host_publishes.load(Ordering::Relaxed), 2);
        assert_eq!(m.host_bytes.load(Ordering::Relaxed), 640);
        assert_eq!(m.resident_hits.load(Ordering::Relaxed), 6);
        assert_eq!(m.resident_misses.load(Ordering::Relaxed), 2);
        let j = m.cache_tiers_json().to_string();
        assert!(j.contains("\"host\"") && j.contains("\"resident\""), "{j}");
        assert!(j.contains("\"disk\""), "{j}");
        assert!(m.report().contains("host(hits=5"), "{}", m.report());
    }

    #[test]
    fn disk_tier_counters_flush() {
        let m = Metrics::new();
        let d = DiskStats {
            hits: 4,
            misses: 2,
            spills: 3,
            loads: 5,
            corrupt: 1,
            corrupt_blocks: 2,
            collisions: 1,
            evictions: 2,
            bytes_loaded: 9000,
            current_bytes: 4096,
            io_errors: 3,
            breaker_opens: 1,
            breaker_closes: 1,
            breaker_short_circuits: 7,
            breaker_open: 1,
            quarantined_bytes: 512,
            quarantine_drops: 2,
        };
        m.record_disk_tier(&d, &[1.5, 2.5]);
        // monotone totals: a second (stale) snapshot can never regress
        m.record_disk_tier(&DiskStats { hits: 3, current_bytes: 1024,
                                        ..DiskStats::default() },
                           &[]);
        assert_eq!(m.disk_hits.load(Ordering::Relaxed), 4);
        assert_eq!(m.disk_spills.load(Ordering::Relaxed), 3);
        assert_eq!(m.disk_corrupt.load(Ordering::Relaxed), 1);
        assert_eq!(m.disk_corrupt_blocks.load(Ordering::Relaxed), 2);
        assert_eq!(m.disk_bytes_loaded.load(Ordering::Relaxed), 9000,
                   "bytes_loaded is monotone");
        // bytes is a gauge: last write wins
        assert_eq!(m.disk_bytes.load(Ordering::Relaxed), 1024);
        // error/breaker totals are monotone; the open flag and the
        // quarantine gauge track the latest snapshot
        assert_eq!(m.disk_io_errors.load(Ordering::Relaxed), 3);
        assert_eq!(m.disk_breaker_opens.load(Ordering::Relaxed), 1);
        assert_eq!(m.disk_breaker_short_circuits.load(Ordering::Relaxed),
                   7);
        assert_eq!(m.disk_breaker_open.load(Ordering::Relaxed), 0);
        assert_eq!(m.disk_quarantined_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(m.disk_quarantine_drops.load(Ordering::Relaxed), 2);
        assert_eq!(m.disk_load.count(), 2);
        assert!((m.disk_load.mean_ms() - 2.0).abs() < 1e-6);
        let j = m.cache_tiers_json().to_string();
        for field in ["\"disk\"", "\"spills\"", "\"loads\"", "\"corrupt\"",
                      "\"corrupt_blocks\"", "\"load_mean_ms\"",
                      "\"load_p50_ms\"", "\"load_p95_ms\"",
                      "\"collisions\"", "\"bytes_loaded\""] {
            assert!(j.contains(field), "{field}: {j}");
        }
        assert!(m.report().contains("disk(hits=4"), "{}", m.report());
    }

    #[test]
    fn fault_counters_flush() {
        use crate::faultinject::{FaultPlan, FaultSite};
        let m = Metrics::new();
        let plan =
            FaultPlan::parse("seed=1;disk_read:count=2").unwrap();
        assert!(plan.should(FaultSite::DiskRead));
        assert!(plan.should(FaultSite::DiskRead));
        assert!(!plan.should(FaultSite::DiskRead), "count cap");
        m.record_faults(&plan);
        m.record_faults(&plan); // stale re-flush can never regress
        assert_eq!(m.faults_injected.load(Ordering::Relaxed), 2);
        assert_eq!(m.faults_disk_read.load(Ordering::Relaxed), 2);
        assert_eq!(m.faults_engine_kill.load(Ordering::Relaxed), 0);
        m.retries.fetch_add(3, Ordering::Relaxed);
        m.timeouts.fetch_add(1, Ordering::Relaxed);
        m.engines_down.store(1, Ordering::Relaxed);
        let j = m.faults_json().to_string();
        for field in ["\"injected\"", "\"disk_read\"", "\"engine_kill\"",
                      "\"peer_fetch\"",
                      "\"retries\"", "\"retry_successes\"",
                      "\"timeouts\"", "\"engine_down_events\"",
                      "\"engines_down\"", "\"disk_io_errors\"",
                      "\"disk_breaker_opens\"",
                      "\"disk_breaker_short_circuits\"",
                      "\"disk_quarantined_bytes\""] {
            assert!(j.contains(field), "{field}: {j}");
        }
        let r = m.report();
        assert!(r.contains("faults(injected=2"), "{r}");
        assert!(r.contains("breaker(open=0"), "{r}");
    }

    #[test]
    fn peer_counters_flush() {
        let m = Metrics::new();
        // direct event counts (each node counts its own fetches)
        m.peer_fetch_hits.fetch_add(3, Ordering::Relaxed);
        m.peer_fetch_misses.fetch_add(2, Ordering::Relaxed);
        m.peer_bytes_in.fetch_add(4096, Ordering::Relaxed);
        m.peer_bytes_out.fetch_add(1024, Ordering::Relaxed);
        m.peers_down.store(1, Ordering::Relaxed);
        m.peer_fetch.observe_ms(1.0);
        m.peer_fetch.observe_ms(3.0);
        let j = m.peers_json().to_string();
        for field in ["\"fetch_hits\"", "\"fetch_misses\"",
                      "\"bytes_in\"", "\"bytes_out\"", "\"down\"",
                      "\"fetch_mean_ms\"", "\"fetch_p50_ms\"",
                      "\"fetch_p95_ms\""] {
            assert!(j.contains(field), "{field}: {j}");
        }
        assert!(j.contains("\"fetch_hits\":3"), "{j}");
        assert!(j.contains("\"bytes_out\":1024"), "{j}");
        assert!(crate::json::parse(&j).is_ok(), "{j}");
        let r = m.report();
        assert!(r.contains("peers(hits=3 misses=2 in=4096 out=1024 \
                            down=1"),
                "{r}");
    }

    #[test]
    fn peers_json_all_zero_on_single_node_stack() {
        // single-node stacks still carry the object (wire consumers
        // need no feature probing) with every counter at zero
        let m = Metrics::new();
        let j = m.peers_json().to_string();
        assert!(j.contains("\"fetch_hits\":0"), "{j}");
        assert!(j.contains("\"down\":0"), "{j}");
        assert!(!j.contains("NaN"), "{j}");
        assert!(crate::json::parse(&j).is_ok(), "{j}");
    }

    #[test]
    fn codec_counters_flush() {
        let m = Metrics::new();
        let snap = CodecSnapshot {
            codec: "int8",
            blocks_encoded: 8,
            blocks_decoded: 5,
            logical_bytes: 4096,
            physical_bytes: 1056,
        };
        m.record_codec(&snap, &[0.2, 0.4]);
        // monotone totals: a stale snapshot can never regress them
        m.record_codec(&CodecSnapshot { codec: "int8", blocks_encoded: 3,
                                        ..CodecSnapshot::default() },
                       &[]);
        assert_eq!(m.codec_blocks_encoded.load(Ordering::Relaxed), 8);
        assert_eq!(m.codec_blocks_decoded.load(Ordering::Relaxed), 5);
        assert_eq!(m.codec_logical_bytes.load(Ordering::Relaxed), 4096);
        assert_eq!(m.codec_physical_bytes.load(Ordering::Relaxed), 1056);
        assert!((m.codec_compression_ratio() - 4096.0 / 1056.0).abs()
                    < 1e-9);
        assert_eq!(m.codec_decode.count(), 2);
        let j = m.codec_json().to_string();
        for field in ["\"codec\"", "\"blocks_encoded\"",
                      "\"blocks_decoded\"", "\"logical_bytes\"",
                      "\"physical_bytes\"", "\"compression_ratio\"",
                      "\"decode_mean_ms\"", "\"decode_p50_ms\"",
                      "\"decode_p95_ms\""] {
            assert!(j.contains(field), "{field}: {j}");
        }
        assert!(j.contains("\"codec\":\"int8\""), "{j}");
        assert!(crate::json::parse(&j).is_ok(), "{j}");
        assert!(m.report().contains("codec(int8 encoded=8"),
                "{}", m.report());
    }

    #[test]
    fn codec_json_defaults_before_any_flush() {
        // an f32 stack that never encodes still serializes cleanly
        let m = Metrics::new();
        assert_eq!(m.codec_compression_ratio(), 1.0);
        let j = m.codec_json().to_string();
        assert!(crate::json::parse(&j).is_ok(), "{j}");
        assert!(j.contains("\"compression_ratio\":1"), "{j}");
    }

    #[test]
    fn pool_counters_flush() {
        let m = Metrics::new();
        let p = PoolStats {
            slots_total: 16,
            slots_live: 10,
            slots_free: 6,
            slab_bytes: 8192,
            grow_events: 2,
            blocks_evicted: 3,
            blocks_spilled: 2,
            share_hits: 5,
            partial_evictions: 1,
            double_frees: 0,
        };
        m.record_pool(&p);
        // event totals are monotone; occupancy gauges track the latest
        // snapshot
        m.record_pool(&PoolStats { slots_total: 16, slots_live: 4,
                                   slots_free: 12, slab_bytes: 8192,
                                   ..PoolStats::default() });
        assert_eq!(m.pool_slots_live.load(Ordering::Relaxed), 4);
        assert_eq!(m.pool_slots_free.load(Ordering::Relaxed), 12);
        assert_eq!(m.pool_grow_events.load(Ordering::Relaxed), 2);
        assert_eq!(m.pool_blocks_evicted.load(Ordering::Relaxed), 3);
        assert_eq!(m.pool_share_hits.load(Ordering::Relaxed), 5);
        assert_eq!(m.pool_partial_evictions.load(Ordering::Relaxed), 1);
        let j = m.pool_json().to_string();
        for field in ["\"slots_total\"", "\"slots_live\"", "\"slots_free\"",
                      "\"slab_bytes\"", "\"grow_events\"",
                      "\"blocks_evicted\"", "\"blocks_spilled\"",
                      "\"share_hits\"", "\"partial_evictions\"",
                      "\"double_frees\""] {
            assert!(j.contains(field), "{field}: {j}");
        }
        assert!(crate::json::parse(&j).is_ok(), "{j}");
        let r = m.report();
        assert!(r.contains("pool(slots=4/16 free=12"), "{r}");
    }

    #[test]
    fn empty_histograms_serialize_finite() {
        // regression: empty histograms must report 0.0 (never NaN), so
        // the wire snapshot and BENCH_serving.json stay valid JSON for
        // the CI regression gate
        let h = Histogram::default();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile_ms(0.50), 0.0);
        assert_eq!(h.percentile_ms(0.95), 0.0);
        let m = Metrics::new();
        for j in [m.serving_json().to_string(),
                  m.cache_tiers_json().to_string()] {
            assert!(!j.contains("NaN") && !j.contains("inf"), "{j}");
            assert!(crate::json::parse(&j).is_ok(), "{j}");
        }
    }

    #[test]
    fn serving_snapshot_reports_scheduler_gauges() {
        let m = Metrics::new();
        m.queue_wait.observe_ms(4.0);
        m.active_sessions.fetch_add(3, Ordering::Relaxed);
        m.fused_rounds.fetch_add(2, Ordering::Relaxed);
        m.fused_round_sessions.fetch_add(5, Ordering::Relaxed);
        m.record_completion(10.0, 5.0, 3, 0);
        let j = m.serving_json().to_string();
        for field in [
            "active_sessions", "queue_wait_mean_ms", "queue_wait_p50_ms",
            "queue_wait_p95_ms", "ttft_p50_ms", "ttft_p95_ms",
            "e2e_p50_ms", "e2e_p95_ms", "fused_rounds",
            "fused_round_sessions", "batched_rounds", "round_executions",
            "executions_per_round", "lane_occupancy",
            "assemble_overlap_ms",
        ] {
            assert!(j.contains(&format!("\"{field}\"")), "{field}: {j}");
        }
        assert!(j.contains("\"active_sessions\":3"), "{j}");
        assert!(j.contains("\"fused_rounds\":2"), "{j}");
        let r = m.report();
        assert!(r.contains("active=3"), "{r}");
        assert!(r.contains("fused(rounds=2 sessions=5)"), "{r}");
    }

    #[test]
    fn decode_round_accounting() {
        let m = Metrics::new();
        // a 3-session round packed into one 4-lane batched execution
        m.record_decode_round(3, 1, 3, 4);
        // a solo round on the scalar path (no batched lanes)
        m.record_decode_round(1, 1, 0, 0);
        assert_eq!(m.fused_rounds.load(Ordering::Relaxed), 2);
        assert_eq!(m.fused_round_sessions.load(Ordering::Relaxed), 4);
        assert_eq!(m.batched_rounds.load(Ordering::Relaxed), 1);
        assert_eq!(m.round_executions.load(Ordering::Relaxed), 2);
        assert!((m.executions_per_round() - 1.0).abs() < 1e-9);
        assert!((m.lane_occupancy() - 0.75).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("batched(rounds=1"), "{r}");
    }

    #[test]
    fn assemble_overlap_accumulates() {
        let m = Metrics::new();
        assert_eq!(m.assemble_overlap_ms(), 0.0);
        m.record_assemble_overlap(1.5);
        m.record_assemble_overlap(2.25);
        assert!((m.assemble_overlap_ms() - 3.75).abs() < 1e-3);
        // negative durations (clock skew) never underflow the counter
        m.record_assemble_overlap(-1.0);
        assert!((m.assemble_overlap_ms() - 3.75).abs() < 1e-3);
    }

    #[test]
    fn derived_ratios_zero_without_rounds() {
        let m = Metrics::new();
        assert_eq!(m.executions_per_round(), 0.0);
        assert_eq!(m.lane_occupancy(), 0.0);
    }

    #[test]
    fn bucket_mapping_sane() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(150), 0);
        assert_eq!(Histogram::bucket_of(200), 1);
        assert_eq!(Histogram::bucket_of(100_000), 9);
        assert_eq!(Histogram::bucket_of(u64::MAX), N_BUCKETS - 1);
    }
}
