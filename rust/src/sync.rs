//! Synchronization facade: every lock in the serving stack goes
//! through here instead of `std::sync` directly.
//!
//! The facade exists for three reasons:
//!
//! 1. **Loom model checking.** Under `RUSTFLAGS="--cfg loom"` the
//!    wrappers are backed by [`loom`](https://docs.rs/loom)'s mock
//!    primitives, so `tests/loom_models.rs` can exhaustively explore
//!    every interleaving of the lease / pool / gate / breaker
//!    protocols. In a normal build the same types are thin wrappers
//!    over `std::sync` with zero behavioural difference.
//! 2. **Lock-order deadlock detection.** With lockcheck enabled
//!    (`SAMKV_LOCKCHECK=1`, `--features lockcheck`, or
//!    [`lockcheck::enable`]) every [`Mutex`]/[`RwLock`] acquisition is
//!    recorded into a global acquisition-order graph keyed by the
//!    lock's *class name* ([`Mutex::named`]); any cycle — two threads
//!    taking two lock classes in opposite orders, anywhere in the
//!    process's lifetime — panics immediately with both lock names
//!    and both acquisition contexts, even if the schedule never
//!    actually deadlocked. When disabled the cost is one relaxed
//!    atomic load per acquisition.
//! 3. **Poison recovery.** `lock()` returns the guard directly,
//!    recovering from poison instead of unwrapping — a panicking
//!    thread must not cascade into `.lock().unwrap()` aborts across
//!    the serving stack (PR 8's supervision turns the original panic
//!    into a structured error; the data under a poisoned lock is
//!    counter/cache state that every consumer revalidates).
//!
//! # What is deliberately *not* wrapped
//!
//! `Arc` stays `std::sync::Arc` in **all** configurations: the block
//! pool's refcounts are its own `refs: Vec<u32>` under the pool mutex
//! (that is what the loom model checks), and keeping one `Arc` type
//! lets migrated and unmigrated modules share handles freely.
//! `mpsc` channels and [`crate::exec::ThreadPool`] likewise stay std:
//! they never participate in the lock-order graph and loom models
//! don't use them.
//!
//! # Canonical lock classes
//!
//! | class | guards | module |
//! |---|---|---|
//! | `host-inner`      | host-tier entry map, in-flight set, pins | `kvcache::store` |
//! | `pin-map`         | one engine's planned-hash pins           | `kvcache::store` |
//! | `kv-blocks`       | one document's block-slot list           | `kvcache::pool`  |
//! | `pool-inner`      | slab, refcounts, free list, content map  | `kvcache::pool`  |
//! | `residency-board` | one engine's advertised hashes           | `kvcache::residency` |
//! | `disk-index`      | disk-tier index, stats, breaker          | `kvcache::disk`  |
//! | `fault-plan`      | fault-injection schedule state           | `faultinject`    |
//! | `gate-slots`      | admission gate permits                   | `exec`           |
//! | `peer-down`       | one peer's down-cooldown deadline        | `server::peers`  |
//! | `front-seeded`    | front-end residency seeding set          | `server::front`  |
//!
//! The canonical acquisition order (an edge means "may be held while
//! taking"):
//!
//! ```text
//! pin-map → host-inner → kv-blocks → pool-inner
//!                      ↘ residency-board
//! disk-index → fault-plan
//! ```
//!
//! Everything else (`gate-slots`, `peer-down`, `front-seeded`) is a
//! leaf: taken and released without acquiring anything beneath it.
//! Lockcheck enforces exactly this: any new nesting that closes a
//! cycle against the recorded graph panics in whichever test first
//! exercises it.

use std::time::Duration;

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(loom)]
pub use loom::thread;
#[cfg(not(loom))]
pub use std::thread;

// std in every configuration — see module docs.
pub use std::sync::Arc;

#[cfg(loom)]
use loom::sync as raw;
#[cfg(not(loom))]
use std::sync as raw;

/// Recover the guard from a (possibly poisoned) lock result. See the
/// module docs for why poison is recovered rather than propagated.
fn recover<G>(r: std::sync::LockResult<G>) -> G {
    match r {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Run `f` under the loom model checker (`--cfg loom`: every
/// interleaving, exhaustively) or as a bounded stress loop with real
/// threads (normal builds: `SAMKV_MODEL_ITERS` iterations, default
/// 64) — the same test body serves both as a model and as a smoke
/// test.
#[cfg(loom)]
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    loom::model(f);
}

/// See the `cfg(loom)` twin above.
#[cfg(not(loom))]
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let iters = std::env::var("SAMKV_MODEL_ITERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(64);
    for _ in 0..iters.max(1) {
        f();
    }
}

/// Mutual exclusion with a lock-class name for deadlock detection.
///
/// API matches `std::sync::Mutex` except that [`Mutex::lock`] returns
/// the guard directly (poison recovered). Prefer [`Mutex::named`] for
/// any lock that can nest with another; `new` labels the lock
/// `"anon"`, which still participates in cycle detection as its own
/// class.
pub struct Mutex<T> {
    name: &'static str,
    inner: raw::Mutex<T>,
}

impl<T> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mutex({})", self.name)
    }
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        Mutex::named("anon", value)
    }

    /// A mutex whose acquisitions are recorded under lock class
    /// `name`. Instances sharing a name form one class: ordering is
    /// checked between classes, not instances (so a `Vec` of
    /// same-purpose locks never self-reports).
    pub fn named(name: &'static str, value: T) -> Mutex<T> {
        Mutex {
            name,
            inner: raw::Mutex::new(value),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        let token =
            lockcheck::on_acquire(self.name, self as *const _ as usize);
        MutexGuard {
            inner: recover(self.inner.lock()),
            token,
        }
    }
}

/// RAII guard from [`Mutex::lock`]. Releases the lockcheck
/// held-record together with the lock.
pub struct MutexGuard<'a, T> {
    inner: raw::MutexGuard<'a, T>,
    token: lockcheck::HeldToken,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// Reader-writer lock with a lock-class name; read and write
/// acquisitions both participate in the acquisition-order graph (a
/// read lock held across another acquisition constrains order exactly
/// like a write lock would once a writer queues behind it).
pub struct RwLock<T> {
    name: &'static str,
    inner: raw::RwLock<T>,
}

impl<T> std::fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RwLock({})", self.name)
    }
}

impl<T> RwLock<T> {
    pub fn new(value: T) -> RwLock<T> {
        RwLock::named("anon", value)
    }

    pub fn named(name: &'static str, value: T) -> RwLock<T> {
        RwLock {
            name,
            inner: raw::RwLock::new(value),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        let token =
            lockcheck::on_acquire(self.name, self as *const _ as usize);
        RwLockReadGuard {
            inner: recover(self.inner.read()),
            token,
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        let token =
            lockcheck::on_acquire(self.name, self as *const _ as usize);
        RwLockWriteGuard {
            inner: recover(self.inner.write()),
            token,
        }
    }
}

pub struct RwLockReadGuard<'a, T> {
    inner: raw::RwLockReadGuard<'a, T>,
    #[allow(dead_code)] // held for its Drop (lockcheck release)
    token: lockcheck::HeldToken,
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

pub struct RwLockWriteGuard<'a, T> {
    inner: raw::RwLockWriteGuard<'a, T>,
    #[allow(dead_code)] // held for its Drop (lockcheck release)
    token: lockcheck::HeldToken,
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// Condition variable over the facade [`Mutex`]. The held-record is
/// dropped for the duration of the wait (the lock really is released)
/// and re-recorded on wakeup, so lockcheck sees the reacquisition.
pub struct Condvar {
    inner: raw::Condvar,
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Condvar")
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar {
            inner: raw::Condvar::new(),
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let MutexGuard { inner, token } = guard;
        let (name, instance) = token.key();
        drop(token);
        MutexGuard {
            inner: recover(self.inner.wait(inner)),
            token: lockcheck::on_acquire(name, instance),
        }
    }

    pub fn wait_while<'a, T, F>(&self, mut guard: MutexGuard<'a, T>,
                                mut cond: F) -> MutexGuard<'a, T>
    where
        F: FnMut(&mut T) -> bool,
    {
        while cond(&mut guard) {
            guard = self.wait(guard);
        }
        guard
    }

    /// Like `std::sync::Condvar::wait_timeout_while`; the second
    /// return is `true` when the wait timed out with `cond` still
    /// holding. Under loom there are no timed waits: the wait is
    /// untimed (never reports a timeout), so loom models must always
    /// eventually satisfy `cond` via a notification.
    #[cfg(not(loom))]
    pub fn wait_timeout_while<'a, T, F>(
        &self, guard: MutexGuard<'a, T>, dur: Duration, cond: F,
    ) -> (MutexGuard<'a, T>, bool)
    where
        F: FnMut(&mut T) -> bool,
    {
        let MutexGuard { inner, token } = guard;
        let (name, instance) = token.key();
        drop(token);
        let (inner, timeout) =
            match self.inner.wait_timeout_while(inner, dur, cond) {
                Ok((g, r)) => (g, r.timed_out()),
                Err(e) => {
                    let (g, r) = e.into_inner();
                    (g, r.timed_out())
                }
            };
        (
            MutexGuard {
                inner,
                token: lockcheck::on_acquire(name, instance),
            },
            timeout,
        )
    }

    /// See the `cfg(not(loom))` twin above.
    #[cfg(loom)]
    pub fn wait_timeout_while<'a, T, F>(
        &self, guard: MutexGuard<'a, T>, _dur: Duration, cond: F,
    ) -> (MutexGuard<'a, T>, bool)
    where
        F: FnMut(&mut T) -> bool,
    {
        (self.wait_while(guard, cond), false)
    }
}

pub mod lockcheck {
    //! Runtime lock-order deadlock detection (see the module docs of
    //! [`super`] for the model). Tracks, per thread, the stack of held
    //! facade locks; every acquisition with locks already held adds
    //! `held-class → new-class` edges to one global directed graph.
    //! An edge that would close a cycle panics with both lock names
    //! and both recorded acquisition contexts. Additionally, relocking
    //! the *same instance* on one thread — a guaranteed std-mutex
    //! self-deadlock — panics immediately.
    //!
    //! Disabled unless `SAMKV_LOCKCHECK` is set to something other
    //! than `0`, the `lockcheck` cargo feature is on, or [`enable`]
    //! was called. Under `cfg(loom)` the whole module is inert (loom
    //! explores deadlocks itself).

    #[cfg(not(loom))]
    use std::cell::RefCell;
    #[cfg(not(loom))]
    use std::collections::HashMap;
    #[cfg(not(loom))]
    use std::sync::atomic::{AtomicU8, Ordering};
    #[cfg(not(loom))]
    use std::sync::{Mutex as StdMutex, OnceLock};

    /// Live record of one held lock; removing it from the per-thread
    /// stack on drop is what keeps the held-set accurate across
    /// arbitrary (non-LIFO) guard drop orders.
    #[derive(Debug)]
    pub struct HeldToken {
        class: &'static str,
        instance: usize,
        active: bool,
    }

    impl HeldToken {
        pub(super) fn key(&self) -> (&'static str, usize) {
            (self.class, self.instance)
        }
    }

    impl Drop for HeldToken {
        fn drop(&mut self) {
            #[cfg(not(loom))]
            if self.active {
                // try_with: thread-teardown may have destroyed the TLS
                let _ = HELD.try_with(|h| {
                    let mut held = h.borrow_mut();
                    if let Some(pos) = held
                        .iter()
                        .rposition(|e| e.instance == self.instance)
                    {
                        held.remove(pos);
                    }
                });
            }
        }
    }

    /// Force detection on for this process (tests use this; servers
    /// use `SAMKV_LOCKCHECK=1` or `--features lockcheck`).
    pub fn enable() {
        #[cfg(not(loom))]
        STATE.store(ON, Ordering::Relaxed);
    }

    #[cfg(not(loom))]
    const UNDECIDED: u8 = 0;
    #[cfg(not(loom))]
    const OFF: u8 = 1;
    #[cfg(not(loom))]
    const ON: u8 = 2;

    #[cfg(not(loom))]
    static STATE: AtomicU8 = AtomicU8::new(UNDECIDED);

    #[cfg(not(loom))]
    fn enabled() -> bool {
        match STATE.load(Ordering::Relaxed) {
            ON => true,
            OFF => false,
            _ => {
                let on = cfg!(feature = "lockcheck")
                    || std::env::var_os("SAMKV_LOCKCHECK")
                        .is_some_and(|v| v != "0");
                STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
                on
            }
        }
    }

    #[cfg(not(loom))]
    #[derive(Debug, Clone, Copy)]
    struct Held {
        class: &'static str,
        instance: usize,
    }

    #[cfg(not(loom))]
    thread_local! {
        static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
    }

    /// Where an ordering edge was first observed — enough to print
    /// "the other stack" when a later acquisition closes a cycle.
    #[cfg(not(loom))]
    #[derive(Debug, Clone)]
    struct EdgeCtx {
        thread: String,
        held: Vec<&'static str>,
    }

    #[cfg(not(loom))]
    #[derive(Debug, Default)]
    struct Graph {
        edges: HashMap<(&'static str, &'static str), EdgeCtx>,
    }

    #[cfg(not(loom))]
    impl Graph {
        /// A path `from → … → to` through recorded edges, if any.
        fn path(&self, from: &'static str, to: &'static str)
                -> Option<Vec<&'static str>> {
            let mut stack = vec![vec![from]];
            let mut seen = vec![from];
            while let Some(path) = stack.pop() {
                let last = *path.last()?;
                if last == to {
                    return Some(path);
                }
                for &(a, b) in self.edges.keys() {
                    if a == last && !seen.contains(&b) {
                        seen.push(b);
                        let mut next = path.clone();
                        next.push(b);
                        stack.push(next);
                    }
                }
            }
            None
        }
    }

    #[cfg(not(loom))]
    fn graph() -> &'static StdMutex<Graph> {
        static GRAPH: OnceLock<StdMutex<Graph>> = OnceLock::new();
        GRAPH.get_or_init(|| StdMutex::new(Graph::default()))
    }

    #[cfg(not(loom))]
    fn thread_name() -> String {
        std::thread::current()
            .name()
            .unwrap_or("<unnamed>")
            .to_string()
    }

    /// Record an acquisition of lock `class` (instance-disambiguated
    /// by address) on the current thread; panics on a detected cycle
    /// or a same-instance relock. Returns the token whose drop
    /// releases the held-record.
    pub(super) fn on_acquire(class: &'static str, instance: usize)
                             -> HeldToken {
        #[cfg(loom)]
        {
            return HeldToken { class, instance, active: false };
        }
        #[cfg(not(loom))]
        {
            if !enabled() {
                return HeldToken { class, instance, active: false };
            }
            HELD.with(|h| {
                let held = h.borrow();
                if held.iter().any(|e| e.instance == instance) {
                    panic!(
                        "lockcheck: thread '{}' relocked '{class}' \
                         (instance {instance:#x}) it already holds — \
                         guaranteed self-deadlock (held: {:?})",
                        thread_name(),
                        held.iter().map(|e| e.class).collect::<Vec<_>>(),
                    );
                }
                if !held.is_empty() {
                    let held_names: Vec<&'static str> =
                        held.iter().map(|e| e.class).collect();
                    let mut g = match graph().lock() {
                        Ok(g) => g,
                        Err(e) => e.into_inner(),
                    };
                    for from in &held_names {
                        // same-class pairs are skipped: instances of
                        // one class (e.g. the per-engine residency
                        // sets) have no order between themselves
                        if *from == class {
                            continue;
                        }
                        if let Some(path) = g.path(class, *from) {
                            let ctx = g
                                .edges
                                .get(&(path[0], path[1]))
                                .cloned()
                                .unwrap_or(EdgeCtx {
                                    thread: "<unknown>".into(),
                                    held: vec![],
                                });
                            panic!(
                                "lockcheck: lock-order cycle — thread \
                                 '{}' is acquiring '{class}' while \
                                 holding {held_names:?}, but the \
                                 opposite order {path:?} was recorded \
                                 on thread '{}' (then holding {:?}). \
                                 One of these nestings must flip to \
                                 the canonical order (see \
                                 crate::sync docs).",
                                thread_name(),
                                ctx.thread,
                                ctx.held,
                            );
                        }
                        g.edges
                            .entry((*from, class))
                            .or_insert_with(|| EdgeCtx {
                                thread: thread_name(),
                                held: held_names.clone(),
                            });
                    }
                }
                drop(held);
                h.borrow_mut().push(Held { class, instance });
            });
            HeldToken { class, instance, active: true }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    // The detector state (enable flag, acquisition graph) is global,
    // so these tests use test-unique class names; enabling lockcheck
    // here also turns it on for every later facade acquisition in
    // this test binary, which is exactly the "suite runs green under
    // lockcheck" property CI wants.

    fn panic_message(r: std::thread::Result<()>) -> String {
        match r {
            Ok(()) => String::new(),
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default(),
        }
    }

    #[test]
    fn opposite_order_reports_both_lock_names() {
        lockcheck::enable();
        let a = Arc::new(Mutex::named("lc-test-a", 0u32));
        let b = Arc::new(Mutex::named("lc-test-b", 0u32));
        // thread 1 records lc-test-a → lc-test-b …
        {
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            thread::spawn(move || {
                let ga = a.lock();
                let _gb = b.lock();
                drop(ga);
            })
            .join()
            .expect("forward order must not trip the detector");
        }
        // … so thread 2 taking lc-test-b → lc-test-a must panic even
        // though no schedule actually deadlocks here (thread 1 is
        // long gone) — the *order* is what is checked.
        let msg = panic_message(
            thread::spawn(move || {
                let gb = b.lock();
                let _ga = a.lock();
                drop(gb);
            })
            .join(),
        );
        assert!(
            msg.contains("lc-test-a") && msg.contains("lc-test-b"),
            "cycle report must name both locks, got: {msg}"
        );
        assert!(msg.contains("cycle"), "not a cycle report: {msg}");
    }

    #[test]
    fn nested_same_order_is_not_a_false_positive() {
        lockcheck::enable();
        let a = Arc::new(Mutex::named("lc-nest-a", 0u32));
        let b = Arc::new(Mutex::named("lc-nest-b", 0u32));
        let c = Arc::new(Mutex::named("lc-nest-c", 0u32));
        // repeated, nested, same-order acquisition across two threads
        for _ in 0..2 {
            let (a, b, c) =
                (Arc::clone(&a), Arc::clone(&b), Arc::clone(&c));
            thread::spawn(move || {
                for _ in 0..3 {
                    let ga = a.lock();
                    let gb = b.lock();
                    let _gc = c.lock();
                    drop(gb); // non-LIFO release is fine too
                    drop(ga);
                }
            })
            .join()
            .expect("same-order nesting must never be reported");
        }
    }

    #[test]
    fn same_class_sibling_instances_are_not_a_cycle() {
        lockcheck::enable();
        // a Vec of same-class locks (the residency-board shape):
        // holding one while taking a sibling must not self-report
        let board: Vec<Mutex<u32>> =
            (0..2).map(|_| Mutex::named("lc-sibling", 0)).collect();
        let g0 = board[0].lock();
        let _g1 = board[1].lock();
        drop(g0);
    }

    #[test]
    fn same_instance_relock_is_reported() {
        lockcheck::enable();
        let a = Arc::new(Mutex::named("lc-relock", 0u32));
        let msg = panic_message(
            thread::spawn(move || {
                let _g1 = a.lock();
                let _g2 = a.lock(); // would deadlock a std mutex
            })
            .join(),
        );
        assert!(
            msg.contains("lc-relock") && msg.contains("self-deadlock"),
            "relock report missing, got: {msg}"
        );
    }

    #[test]
    fn condvar_wait_releases_and_rerecords_the_held_lock() {
        lockcheck::enable();
        let pair =
            Arc::new((Mutex::named("lc-cv", false), Condvar::new()));
        let waiter = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let (m, cv) = &*pair;
                let g = cv.wait_while(m.lock(), |done| !*done);
                assert!(*g);
            })
        };
        let (m, cv) = &*pair;
        loop {
            let mut g = m.lock();
            *g = true;
            cv.notify_all();
            drop(g);
            break;
        }
        waiter.join().expect("waiter must wake cleanly");
    }

    #[test]
    fn wait_timeout_while_reports_timeout() {
        let m = Mutex::named("lc-cv-timeout", ());
        let cv = Condvar::new();
        let (_g, timed_out) = cv.wait_timeout_while(
            m.lock(),
            Duration::from_millis(10),
            |()| true,
        );
        assert!(timed_out);
    }

    #[test]
    fn rwlock_read_write_roundtrip() {
        let l = RwLock::named("lc-rw", 1u32);
        assert_eq!(*l.read(), 1);
        *l.write() = 2;
        assert_eq!(*l.read(), 2);
    }

    #[test]
    fn poisoned_mutex_recovers() {
        let m = Arc::new(Mutex::named("lc-poison", 7u32));
        let m2 = Arc::clone(&m);
        let _ = thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        assert_eq!(*m.lock(), 7, "poison must recover, not propagate");
    }
}
