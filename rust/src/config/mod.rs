//! Configuration: model/serving profiles and policy knobs.
//!
//! [`ProfileConfig`] mirrors `python/compile/taskspec.py::Profile` and is
//! loaded from `artifacts/manifest.json` (the build emits the derived
//! shapes, so the two sides cannot drift silently). [`SamKvConfig`] and
//! [`ServingConfig`] are the runtime knobs.

use crate::json::Value;
use anyhow::Result;

/// Static model/task geometry for one AOT profile (s4 / m6 / tiny).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub n_docs: usize,
    pub doc_len: usize,
    pub block_size: usize,
    pub init_blocks: usize,
    pub local_blocks: usize,
    pub sel_cap_blocks: usize,
    pub stable_layers: usize,
    pub rope_theta: f64,
    pub query_len: usize,
    pub answer_max: usize,
    pub ctx_len: usize,
    pub full_len: usize,
    pub sparse_kv_len: usize,
    pub sparse_len: usize,
    pub comp_len: usize,
    pub blocks_per_doc: usize,
    /// Lane count of the batched decode entry points
    /// (`decode_{sparse,full}_batched`): one fused serving round packs
    /// up to this many sequences into a single XLA execution. Baked
    /// into the artifact shapes; defaults to 4 for manifests predating
    /// the batched entries.
    pub decode_lanes: usize,
}

impl ProfileConfig {
    pub fn from_json(v: &Value) -> Result<ProfileConfig> {
        let u = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad usize field `{k}`"))
        };
        Ok(ProfileConfig {
            name: v
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("bad name"))?
                .to_string(),
            n_layers: u("n_layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            vocab: u("vocab")?,
            n_docs: u("n_docs")?,
            doc_len: u("doc_len")?,
            block_size: u("block_size")?,
            init_blocks: u("init_blocks")?,
            local_blocks: u("local_blocks")?,
            sel_cap_blocks: u("sel_cap_blocks")?,
            stable_layers: u("stable_layers")?,
            rope_theta: v.req("rope_theta")?.as_f64().unwrap_or(10_000.0),
            query_len: u("query_len")?,
            answer_max: u("answer_max")?,
            ctx_len: u("ctx_len")?,
            full_len: u("full_len")?,
            sparse_kv_len: u("sparse_kv_len")?,
            sparse_len: u("sparse_len")?,
            comp_len: u("comp_len")?,
            blocks_per_doc: u("blocks_per_doc")?,
            decode_lanes: v
                .get("decode_lanes")
                .and_then(|x| x.as_usize())
                .unwrap_or(4),
        })
    }

    /// Number of init+local blocks kept at full resolution per document.
    pub fn fixed_blocks_per_doc(&self) -> usize {
        self.init_blocks + self.local_blocks
    }

    /// Middle (sparsifiable) blocks per document.
    pub fn middle_blocks_per_doc(&self) -> usize {
        self.blocks_per_doc - self.fixed_blocks_per_doc()
    }

    /// The first layer index inside the stable window N* (Eq. 3 uses the
    /// trailing `stable_layers` layers; Appendix A.2).
    pub fn stable_layer_start(&self) -> usize {
        self.n_layers - self.stable_layers.min(self.n_layers)
    }

    /// Global (joint-layout) position of the first token of doc `i`.
    pub fn doc_offset(&self, doc: usize) -> usize {
        doc * self.doc_len
    }

    /// KV bytes per token (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.head_dim * 4
    }
}

/// Which write-back strategy the recomputation module uses (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateStrategy {
    /// Replace old cache entries with the recomputed values.
    Overwrite,
    /// Eq. 4: `new = θ·new + (1-θ)·old`, θ = cos(new, old).
    Fusion,
}

impl std::str::FromStr for UpdateStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "overwrite" => Ok(UpdateStrategy::Overwrite),
            "fusion" => Ok(UpdateStrategy::Fusion),
            _ => anyhow::bail!("unknown update strategy `{s}`"),
        }
    }
}

/// SamKV pipeline knobs (the Table-4 ablation axes are all here).
#[derive(Debug, Clone)]
pub struct SamKvConfig {
    /// Select middle KV blocks (ablation column "Selection").
    pub selection: bool,
    /// Personalized bias (Eq. 1) on the query vector ("PersBias.").
    pub pers_bias: bool,
    /// Recompute the sparsified tokens ("Recompute").
    pub recompute: bool,
    /// Overwrite vs fusion write-back (§3.3, Eq. 4).
    pub update: UpdateStrategy,
    /// PauTa criterion multiplier for outlier-token recomputation
    /// (Appendix A.1; the classical criterion is 3σ).
    pub pauta_sigma: f32,
    /// Use the offloaded `score_blocks` artifact instead of host scoring.
    pub offload_scoring: bool,
}

impl Default for SamKvConfig {
    fn default() -> Self {
        SamKvConfig {
            selection: true,
            pers_bias: true,
            recompute: true,
            update: UpdateStrategy::Fusion,
            pauta_sigma: 3.0,
            offload_scoring: false,
        }
    }
}

/// When host-tier document-cache entries reach the persistent disk
/// tier (`--disk-writeback`, see [`crate::kvcache::DiskDocCache`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiskWriteback {
    /// Spill on host-tier eviction only (writeback): an entry reaches
    /// disk the moment RAM would otherwise drop it.
    Evict,
    /// Write-through: every host-tier insert is persisted immediately
    /// (evictions then find their file already on disk).
    Through,
    /// Never write. The disk tier is still *read* when attached, so a
    /// pre-seeded cache directory can warm-start a server.
    Off,
}

impl DiskWriteback {
    pub fn name(self) -> &'static str {
        match self {
            DiskWriteback::Evict => "evict",
            DiskWriteback::Through => "through",
            DiskWriteback::Off => "off",
        }
    }
}

impl std::str::FromStr for DiskWriteback {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "evict" => Ok(DiskWriteback::Evict),
            "through" => Ok(DiskWriteback::Through),
            "off" => Ok(DiskWriteback::Off),
            _ => anyhow::bail!("unknown disk writeback mode `{s}` \
                                (expected evict|through|off)"),
        }
    }
}

/// How KV block payloads are byte-encoded when they leave the hot
/// path — host-tier blocks past the `--kv-hot-blocks` watermark and
/// every disk-tier block record (`--kv-codec`, see
/// [`crate::kvcache::codec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvCodecKind {
    /// Lossless little-endian f32 (default): byte-identical round
    /// trip, no compression.
    F32,
    /// IEEE half precision, 2× smaller, hand-rolled bit conversion.
    F16,
    /// Per-block absmax int8 (one f32 scale per block), ~4× smaller.
    Int8,
}

impl KvCodecKind {
    pub fn name(self) -> &'static str {
        match self {
            KvCodecKind::F32 => "f32",
            KvCodecKind::F16 => "f16",
            KvCodecKind::Int8 => "int8",
        }
    }
}

impl std::str::FromStr for KvCodecKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(KvCodecKind::F32),
            "f16" => Ok(KvCodecKind::F16),
            "int8" => Ok(KvCodecKind::Int8),
            _ => anyhow::bail!("unknown KV codec `{s}` \
                                (expected f32|f16|int8)"),
        }
    }
}

/// Serving-stack knobs.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub artifacts_dir: String,
    pub profile: String,
    pub workers: usize,
    /// Largest admission wave: how many queued requests one gather on
    /// the engine's admission helper thread may pull in at once (also
    /// bounded by the decode pool's free slots).
    pub max_batch: usize,
    pub queue_capacity: usize,
    pub port: u16,
    /// Gather window (`--batch-window-ms`): once at least one request
    /// is in hand, how long the admission helper keeps gathering more
    /// before the wave runs its staged admission. Admission lives on
    /// its own thread, so this window never stalls a decode round.
    pub batch_window_ms: u64,
    /// Cap on concurrently decoding sessions (`--max-active`): the
    /// admission helper reserves decode-pool slots on a counting gate
    /// before gathering a wave, so the pool never exceeds this; slots
    /// return as sessions retire.
    pub max_active: usize,
    /// Directory of the persistent disk document-cache tier
    /// (`--disk-cache-dir`); empty disables the tier, and every
    /// restart then re-prefills the corpus from scratch.
    pub disk_cache_dir: String,
    /// Disk-tier byte budget in MiB (`--disk-cache-mb`; 0 = unbounded,
    /// the tier then grows with the corpus).
    pub disk_cache_mb: usize,
    /// Host-tier → disk-tier writeback mode (`--disk-writeback`).
    pub disk_writeback: DiskWriteback,
    /// Token span of one KV pool block (`--kv-block-tokens`): the unit
    /// of slab allocation, eviction, spill, and prefix sharing in
    /// [`crate::kvcache::KvBlockPool`]. Smaller blocks evict and share
    /// at finer grain but cost more per-block bookkeeping.
    pub kv_block_tokens: usize,
    /// Block payload encoding for cold host blocks and all disk
    /// records (`--kv-codec`). `F32` keeps every block in the pool at
    /// full precision (byte-identical serving); `F16`/`Int8` trade
    /// tolerance-bounded precision for 2–4× more documents per byte
    /// budget and proportionally fewer bytes moved per tier crossing.
    pub kv_codec: KvCodecKind,
    /// Hot watermark (`--kv-hot-blocks`): a document's first N blocks
    /// stay pooled at full f32 precision even under a lossy codec (the
    /// head of a document carries the retrieval-critical KV); blocks
    /// at or past the watermark are stored encoded. Ignored under
    /// `F32`. 0 encodes every block.
    pub kv_hot_blocks: usize,
    /// Seeded fault schedule (`--fault-plan`, see
    /// [`crate::faultinject::FaultPlan`]); `None` injects nothing.
    /// Shared across engines and the disk tier so counters are
    /// process-wide.
    pub fault_plan: Option<std::sync::Arc<crate::faultinject::FaultPlan>>,
    /// Per-request deadline in ms (`--request-timeout-ms`), enforced
    /// at admission (queue wait + plan/prefill), per decode round, and
    /// as a server-side backstop. 0 disables deadlines.
    pub request_timeout_ms: u64,
    /// Server-side resubmissions to a surviving engine after an
    /// engine-down failure (`--request-retries`); 0 fails fast.
    pub request_retries: usize,
    /// Base backoff before a retry (`--retry-backoff-ms`); the actual
    /// sleep is jittered in [base/2, base) per attempt.
    pub retry_backoff_ms: u64,
    /// Disk-tier circuit breaker: this many *consecutive* I/O errors
    /// open it (`--disk-breaker-threshold`; 0 disables the breaker).
    /// Open means every lookup short-circuits to a miss and
    /// writebacks are skipped.
    pub disk_breaker_threshold: usize,
    /// How long the breaker stays open before a half-open probe lets
    /// one disk operation through (`--disk-breaker-probe-ms`);
    /// probe success re-closes it, failure re-opens.
    pub disk_breaker_probe_ms: u64,
    /// Cluster peer addresses (`--peers host:port,host:port,…`), one
    /// per node **including this node's own address** — the list's
    /// order defines node ids and must be identical on every node so
    /// rendezvous ownership agrees cluster-wide. Empty disables the
    /// peer tier (single-node mode).
    pub peers: Vec<String>,
    /// This process's index into `peers` (`--node-id`).
    pub node_id: usize,
    /// Connect/read/write timeout for one peer fetch
    /// (`--peer-timeout-ms`). A timeout is a miss — the request falls
    /// back to a local prefill, so this bounds the worst-case added
    /// latency of a down peer.
    pub peer_timeout_ms: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".to_string(),
            profile: "s4".to_string(),
            workers: 1,
            max_batch: 4,
            queue_capacity: 256,
            port: 7070,
            batch_window_ms: 2,
            max_active: 8,
            disk_cache_dir: String::new(),
            disk_cache_mb: 0,
            disk_writeback: DiskWriteback::Evict,
            kv_block_tokens: crate::kvcache::DEFAULT_KV_BLOCK_TOKENS,
            kv_codec: KvCodecKind::F32,
            kv_hot_blocks: DEFAULT_KV_HOT_BLOCKS,
            fault_plan: None,
            request_timeout_ms: 0,
            request_retries: DEFAULT_REQUEST_RETRIES,
            retry_backoff_ms: DEFAULT_RETRY_BACKOFF_MS,
            disk_breaker_threshold: DEFAULT_DISK_BREAKER_THRESHOLD,
            disk_breaker_probe_ms: DEFAULT_DISK_BREAKER_PROBE_MS,
            peers: Vec::new(),
            node_id: 0,
            peer_timeout_ms: DEFAULT_PEER_TIMEOUT_MS,
        }
    }
}

/// Default `--request-retries`: one resubmission to a surviving
/// engine after an engine-down failure.
pub const DEFAULT_REQUEST_RETRIES: usize = 2;

/// Default `--retry-backoff-ms` base for jittered retry backoff.
pub const DEFAULT_RETRY_BACKOFF_MS: u64 = 10;

/// Default `--disk-breaker-threshold`: consecutive disk I/O errors
/// before the breaker opens.
pub const DEFAULT_DISK_BREAKER_THRESHOLD: usize = 5;

/// Default `--disk-breaker-probe-ms`: open-state dwell before one
/// half-open probe is admitted.
pub const DEFAULT_DISK_BREAKER_PROBE_MS: u64 = 500;

/// Default `--kv-hot-blocks`: how many leading blocks of a document
/// stay at full f32 precision under a lossy codec.
pub const DEFAULT_KV_HOT_BLOCKS: usize = 4;

/// Default `--peer-timeout-ms`: per-fetch peer transport deadline.
/// Deliberately tight — a peer fetch races against "just prefill it
/// locally", so waiting longer than a typical prefill is never worth
/// it.
pub const DEFAULT_PEER_TIMEOUT_MS: u64 = 250;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn sample_profile_json() -> Value {
        json::parse(
            r#"{"name":"tiny","n_layers":2,"d_model":48,"n_heads":2,
                "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
                "block_size":8,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":4}"#,
        )
        .unwrap()
    }

    #[test]
    fn profile_from_json() {
        let p = ProfileConfig::from_json(&sample_profile_json()).unwrap();
        assert_eq!(p.name, "tiny");
        assert_eq!(p.n_layers, 2);
        assert_eq!(p.fixed_blocks_per_doc(), 2);
        assert_eq!(p.middle_blocks_per_doc(), 2);
        assert_eq!(p.stable_layer_start(), 1);
        assert_eq!(p.doc_offset(1), 32);
        assert_eq!(p.kv_bytes_per_token(), 2 * 2 * 2 * 24 * 4);
        // absent from older manifests: defaults to 4 lanes
        assert_eq!(p.decode_lanes, 4);
    }

    #[test]
    fn decode_lanes_parsed_when_present() {
        let mut s = r#"{"name":"tiny","n_layers":2,"d_model":48,"n_heads":2,
                "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
                "block_size":8,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":4"#
            .to_string();
        s.push_str(r#","decode_lanes":8}"#);
        let p = ProfileConfig::from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(p.decode_lanes, 8);
    }

    #[test]
    fn missing_field_errors() {
        let v = json::parse(r#"{"name":"x"}"#).unwrap();
        assert!(ProfileConfig::from_json(&v).is_err());
    }

    #[test]
    fn serving_defaults() {
        let c = ServingConfig::default();
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.batch_window_ms, 2);
        assert!(c.max_active >= c.max_batch,
                "default pool must fit a full admission wave");
        assert_eq!(c.kv_block_tokens,
                   crate::kvcache::DEFAULT_KV_BLOCK_TOKENS);
        assert_eq!(c.kv_block_tokens, 64);
    }

    #[test]
    fn disk_writeback_parse_and_default() {
        assert_eq!("evict".parse::<DiskWriteback>().unwrap(),
                   DiskWriteback::Evict);
        assert_eq!("through".parse::<DiskWriteback>().unwrap(),
                   DiskWriteback::Through);
        assert_eq!("off".parse::<DiskWriteback>().unwrap(),
                   DiskWriteback::Off);
        assert!("sync".parse::<DiskWriteback>().is_err());
        assert_eq!(DiskWriteback::Through.name(), "through");
        let c = ServingConfig::default();
        assert!(c.disk_cache_dir.is_empty(), "disk tier defaults off");
        assert_eq!(c.disk_writeback, DiskWriteback::Evict);
    }

    #[test]
    fn kv_codec_parse_rejects_unknown_with_listing() {
        assert_eq!("f32".parse::<KvCodecKind>().unwrap(),
                   KvCodecKind::F32);
        assert_eq!("f16".parse::<KvCodecKind>().unwrap(),
                   KvCodecKind::F16);
        assert_eq!("int8".parse::<KvCodecKind>().unwrap(),
                   KvCodecKind::Int8);
        for kind in
            [KvCodecKind::F32, KvCodecKind::F16, KvCodecKind::Int8]
        {
            assert_eq!(kind.name().parse::<KvCodecKind>().unwrap(), kind);
        }
        // an unknown name must error AND list the valid codecs
        let err = "bf16".parse::<KvCodecKind>().unwrap_err().to_string();
        assert!(err.contains("bf16"), "{err}");
        assert!(err.contains("f32") && err.contains("f16")
                && err.contains("int8"), "{err}");
        let c = ServingConfig::default();
        assert_eq!(c.kv_codec, KvCodecKind::F32,
                   "lossless must stay the default");
        assert_eq!(c.kv_hot_blocks, DEFAULT_KV_HOT_BLOCKS);
    }

    #[test]
    fn resilience_defaults() {
        let c = ServingConfig::default();
        assert!(c.fault_plan.is_none(), "no faults unless asked");
        assert_eq!(c.request_timeout_ms, 0, "deadlines default off");
        assert_eq!(c.request_retries, DEFAULT_REQUEST_RETRIES);
        assert_eq!(c.retry_backoff_ms, DEFAULT_RETRY_BACKOFF_MS);
        assert_eq!(c.disk_breaker_threshold,
                   DEFAULT_DISK_BREAKER_THRESHOLD);
        assert!(c.disk_breaker_threshold > 1,
                "one transient error must not open the breaker");
        assert_eq!(c.disk_breaker_probe_ms,
                   DEFAULT_DISK_BREAKER_PROBE_MS);
        // the config (and its fault plan) must stay debuggable
        let d = format!("{c:?}");
        assert!(d.contains("fault_plan: None"), "{d}");
    }

    #[test]
    fn peer_defaults_single_node() {
        let c = ServingConfig::default();
        assert!(c.peers.is_empty(), "peer tier defaults off");
        assert_eq!(c.node_id, 0);
        assert_eq!(c.peer_timeout_ms, DEFAULT_PEER_TIMEOUT_MS);
        assert!(c.peer_timeout_ms > 0,
                "a zero transport deadline would hang fetches");
    }

    #[test]
    fn update_strategy_parse() {
        assert_eq!("fusion".parse::<UpdateStrategy>().unwrap(),
                   UpdateStrategy::Fusion);
        assert_eq!("overwrite".parse::<UpdateStrategy>().unwrap(),
                   UpdateStrategy::Overwrite);
        assert!("blend".parse::<UpdateStrategy>().is_err());
    }
}
