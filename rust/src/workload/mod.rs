//! Workloads: evaluation datasets emitted by the AOT build (the
//! LongBench stand-ins) and synthetic load generation for throughput
//! benches.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::ProfileConfig;
use crate::json;
use crate::rng::Rng;
use crate::tokenizer as tok;

/// One multi-document QA sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub docs: Vec<Vec<i32>>,
    pub query: Vec<i32>,
    pub answer: Vec<i32>,
    pub qtype: String,
}

/// A loaded evaluation dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub profile: String,
    pub dataset: String,
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(
            || format!("reading dataset {}", path.as_ref().display()),
        )?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Dataset> {
        let v = json::parse(text)?;
        let samples = v
            .req("samples")?
            .as_arr()
            .ok_or_else(|| anyhow!("samples not an array"))?
            .iter()
            .map(|s| {
                let docs = s
                    .req("docs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("docs not an array"))?
                    .iter()
                    .map(|d| d.i32_vec().ok_or_else(|| anyhow!("bad doc")))
                    .collect::<Result<Vec<_>>>()?;
                Ok(Sample {
                    docs,
                    query: s
                        .req("query")?
                        .i32_vec()
                        .ok_or_else(|| anyhow!("bad query"))?,
                    answer: s
                        .req("answer")?
                        .i32_vec()
                        .ok_or_else(|| anyhow!("bad answer"))?,
                    qtype: s
                        .req("qtype")?
                        .as_str()
                        .unwrap_or("unknown")
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Dataset {
            profile: v
                .req("profile")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            dataset: v
                .req("dataset")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            samples,
        })
    }
}

/// Joint (training-layout) sequence assembly — mirrors
/// `python/compile/data.py::assemble_full`. Returns
/// `(tokens, valid, ans_start)` padded to `cfg.full_len`.
pub fn assemble_full(sample: &Sample, cfg: &ProfileConfig)
                     -> (Vec<i32>, Vec<f32>, usize) {
    let mut seq: Vec<i32> = Vec::with_capacity(cfg.full_len);
    for d in &sample.docs {
        seq.extend_from_slice(d);
    }
    seq.extend_from_slice(&sample.query);
    let ans_start = seq.len();
    assert!(seq.len() <= cfg.full_len);
    let mut tokens = vec![tok::PAD; cfg.full_len];
    tokens[..seq.len()].copy_from_slice(&seq);
    let mut valid = vec![0.0f32; cfg.full_len];
    for v in valid.iter_mut().take(seq.len()) {
        *v = 1.0;
    }
    (tokens, valid, ans_start)
}

/// Synthetic sample with arbitrary (untrained-distribution) content —
/// used by throughput/latency benches where answer quality is
/// irrelevant. Facts are still planted so selection has structure.
pub fn synthetic_sample(cfg: &ProfileConfig, rng: &mut Rng) -> Sample {
    let mut docs = Vec::with_capacity(cfg.n_docs);
    for _ in 0..cfg.n_docs {
        let mut d = Vec::with_capacity(cfg.doc_len);
        d.push(tok::BOS);
        while d.len() < cfg.doc_len {
            if rng.next_f32() < 0.15 && d.len() + 2 <= cfg.doc_len {
                d.push(tok::key_tok(rng.below(tok::N_KEYS as usize) as i32));
                d.push(tok::val_tok(rng.below(tok::N_VALS as usize) as i32));
            } else {
                d.push(tok::filler_tok(
                    rng.below(tok::N_FILLERS as usize) as i32,
                ));
            }
        }
        docs.push(d);
    }
    let k = tok::key_tok(rng.below(tok::N_KEYS as usize) as i32);
    Sample {
        docs,
        query: vec![tok::QUERY, tok::NOORD, k, tok::PAD, tok::ANS],
        answer: vec![tok::val_tok(0)],
        qtype: "synthetic".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"tiny","n_layers":2,"d_model":48,"n_heads":2,
                "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
                "block_size":8,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":4}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    #[test]
    fn dataset_from_json() {
        let d = Dataset::from_json_str(
            r#"{"profile":"tiny","dataset":"hotpot-sim","seed":1,
                "samples":[{"docs":[[1,2],[1,3]],"query":[2,5,16,0,3],
                            "answer":[80],"qtype":"single"}]}"#,
        )
        .unwrap();
        assert_eq!(d.samples.len(), 1);
        assert_eq!(d.samples[0].docs[1], vec![1, 3]);
        assert_eq!(d.samples[0].answer, vec![80]);
    }

    #[test]
    fn assemble_layout() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let s = synthetic_sample(&cfg, &mut rng);
        let (tokens, valid, ans_start) = assemble_full(&s, &cfg);
        assert_eq!(tokens.len(), cfg.full_len);
        assert_eq!(ans_start, cfg.ctx_len + cfg.query_len);
        assert_eq!(tokens[ans_start - 1], tok::ANS);
        assert_eq!(tokens[0], tok::BOS);
        assert_eq!(tokens[cfg.doc_len], tok::BOS); // doc 2 starts with BOS
        assert_eq!(valid[ans_start - 1], 1.0);
        assert_eq!(valid[ans_start], 0.0);
    }

    #[test]
    fn synthetic_docs_are_well_formed() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let s = synthetic_sample(&cfg, &mut rng);
            assert_eq!(s.docs.len(), cfg.n_docs);
            for d in &s.docs {
                assert_eq!(d.len(), cfg.doc_len);
                assert_eq!(d[0], tok::BOS);
            }
            assert_eq!(s.query.len(), cfg.query_len);
        }
    }

    #[test]
    fn real_tiny_dataset_if_present() {
        let dir = crate::runtime::artifacts_dir();
        let p = dir.join("datasets/d2x32_hotpot-sim.json");
        if p.exists() {
            let d = Dataset::load(&p).unwrap();
            assert!(!d.samples.is_empty());
            let cfg = tiny_cfg();
            for s in &d.samples {
                assert_eq!(s.docs.len(), cfg.n_docs);
                assert_eq!(s.query.len(), cfg.query_len);
                assert!(!s.answer.is_empty());
            }
        }
    }
}
