//! PauTa (3σ) criterion — the paper's outlier test for both
//! recomputation-token detection (A.1) and layer-stability scoring (A.2).

use crate::tensor::{mean, std_dev};

/// Indices whose value deviates from the mean by more than `sigma`
/// standard deviations (either direction).
pub fn pauta_outliers(xs: &[f32], sigma: f32) -> Vec<usize> {
    let m = mean(xs);
    let s = std_dev(xs);
    if s < 1e-12 {
        return Vec::new();
    }
    xs.iter()
        .enumerate()
        .filter(|(_, &x)| (x - m).abs() > sigma * s)
        .map(|(i, _)| i)
        .collect()
}

/// Low-side outliers only — for power-law exponents a *low* alpha means
/// unusually strong sustained attention (the tokens worth recomputing).
pub fn pauta_low_outliers(xs: &[f32], sigma: f32) -> Vec<usize> {
    let m = mean(xs);
    let s = std_dev(xs);
    if s < 1e-12 {
        return Vec::new();
    }
    xs.iter()
        .enumerate()
        .filter(|(_, &x)| m - x > sigma * s)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_obvious_outlier() {
        let mut xs = vec![1.0f32; 20];
        xs[7] = 30.0;
        assert_eq!(pauta_outliers(&xs, 3.0), vec![7]);
    }

    #[test]
    fn constant_series_has_none() {
        assert!(pauta_outliers(&[2.0; 10], 3.0).is_empty());
        assert!(pauta_low_outliers(&[2.0; 10], 3.0).is_empty());
    }

    #[test]
    fn low_outliers_are_one_sided() {
        let mut xs = vec![5.0f32; 30];
        xs[3] = -20.0; // low outlier
        xs[9] = 30.0; // high outlier
        assert_eq!(pauta_low_outliers(&xs, 2.0), vec![3]);
        let both = pauta_outliers(&xs, 2.0);
        assert!(both.contains(&3) && both.contains(&9));
    }

    #[test]
    fn sigma_controls_sensitivity() {
        let xs: Vec<f32> = (0..40).map(|i| (i % 5) as f32).collect();
        assert!(pauta_outliers(&xs, 0.1).len()
                    > pauta_outliers(&xs, 3.0).len());
    }
}
