//! A.2 — cross-layer block-importance stability and N* selection.
//!
//! For each analyzed document: average every block's importance rank
//! across layers, take the globally best block β, and credit a layer
//! whenever β's rank within that layer is a PauTa low-outlier (i.e. the
//! layer decisively agrees that β dominates). Layers with high scores
//! have *stable* attention; the paper takes the trailing high-score
//! layers as N* (Fig. 8 plots these scores per dataset).

use super::analysis::BlockAttention;
use super::pauta::pauta_low_outliers;

/// Per-layer stability scores in [0, 1] (fraction of documents whose
/// best block is a decisive outlier in that layer).
pub fn layer_stability_scores(docs: &[&BlockAttention], pauta_sigma: f32)
                              -> Vec<f32> {
    assert!(!docs.is_empty());
    let nl = docs[0].n_layers;
    let mut scores = vec![0f32; nl];
    for ba in docs {
        debug_assert_eq!(ba.n_layers, nl);
        let nb = ba.n_blocks;
        // global best block: lowest mean rank across layers
        let beta = (0..nb)
            .min_by(|&a, &b| {
                let ra: f32 = (0..nl)
                    .map(|l| ba.importance_rank[l][a] as f32)
                    .sum();
                let rb: f32 = (0..nl)
                    .map(|l| ba.importance_rank[l][b] as f32)
                    .sum();
                ra.partial_cmp(&rb).unwrap()
            })
            .unwrap();
        for l in 0..nl {
            // a layer is stable w.r.t. β when it (a) ranks β first and
            // (b) β's α is a decisive PauTa low-outlier among the
            // layer's αs (ranks alone are permutation-invariant and
            // carry no significance signal)
            if ba.importance_rank[l][beta] != 0 {
                continue;
            }
            let alphas = &ba.alpha[l];
            if pauta_low_outliers(alphas, pauta_sigma).contains(&beta) {
                scores[l] += 1.0;
            }
        }
    }
    for s in scores.iter_mut() {
        *s /= docs.len() as f32;
    }
    scores
}

/// Choose the N* layer set: the `k` highest-scoring layers, breaking
/// ties toward the *latest* layers (the paper observes stability
/// concentrates in the final layers).
pub fn select_stable_layers(scores: &[f32], k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap()
            .then(b.cmp(&a)) // later layer wins ties
    });
    let mut out: Vec<usize> = order.into_iter().take(k).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a BlockAttention from per-layer α vectors (ranks derived).
    fn fake_ba(alphas: Vec<Vec<f32>>) -> BlockAttention {
        let nl = alphas.len();
        let nb = alphas[0].len();
        let ranks: Vec<Vec<usize>> = alphas
            .iter()
            .map(|layer| {
                let mut order: Vec<usize> = (0..nb).collect();
                order.sort_by(|&a, &b| {
                    layer[a].partial_cmp(&layer[b]).unwrap()
                });
                let mut rank = vec![0usize; nb];
                for (r, &b) in order.iter().enumerate() {
                    rank[b] = r;
                }
                rank
            })
            .collect();
        BlockAttention {
            n_layers: nl,
            n_blocks: nb,
            rep_token: vec![vec![0; nb]; nl],
            alpha: alphas,
            mean_received: vec![vec![0.0; nb]; nl],
            importance_rank: ranks,
            outlier_tokens: vec![Vec::new(); nl],
        }
    }

    // clustered αs + two high stragglers: the narrow best (block 5) is
    // well inside 1.2σ, so no layer can call it significant
    const FLAT: [f32; 8] = [1.0, 0.995, 1.005, 1.0, 1.01, 0.99, 1.2, 1.2];
    // block 0 decisively dominant
    const SPIKY: [f32; 8] = [0.05, 1.2, 1.3, 1.1, 1.25, 1.15, 1.2, 1.3];

    #[test]
    fn stable_layer_scores_higher() {
        // 8 blocks; block 0 is globally best (avg rank). Layer 1 makes it
        // a decisive α outlier; layer 0 doesn't even rank it first.
        let ba = fake_ba(vec![FLAT.to_vec(), SPIKY.to_vec()]);
        let scores = layer_stability_scores(&[&ba], 1.2);
        assert!(scores[1] > scores[0], "scores {scores:?}");
        assert_eq!(scores[1], 1.0);
        assert_eq!(scores[0], 0.0);
    }

    #[test]
    fn scores_are_fractions_over_docs() {
        // doc A: decisive outlier at block 0 -> layer counted.
        let stable = fake_ba(vec![SPIKY.to_vec()]);
        // doc B: flat αs -> best block not significant -> not counted.
        let unstable = fake_ba(vec![FLAT.to_vec()]);
        let scores = layer_stability_scores(&[&stable, &unstable], 1.2);
        assert_eq!(scores.len(), 1);
        assert!((scores[0] - 0.5).abs() < 1e-6, "scores {scores:?}");
    }

    #[test]
    fn select_prefers_late_layers_on_ties() {
        let scores = vec![0.2, 0.8, 0.8, 0.2];
        assert_eq!(select_stable_layers(&scores, 2), vec![1, 2]);
        let flat = vec![0.5, 0.5, 0.5, 0.5];
        assert_eq!(select_stable_layers(&flat, 2), vec![2, 3]);
    }

    #[test]
    fn select_handles_k_larger_than_layers() {
        assert_eq!(select_stable_layers(&[0.1, 0.9], 5), vec![0, 1]);
    }
}
