//! A.1 — dual-score block characterization from prefill attention maps.
//!
//! For every (layer, block) we compute:
//! * the **representative token** — the token receiving the highest mean
//!   attention from subsequent queries (the "bright line" in Fig. 7);
//! * the **importance attribute** — the power-law exponent α of the
//!   representative token's received-attention curve (smaller α = the
//!   attention is sustained over distance = more important);
//! * the **unimportance attribute** — the representative token's mean
//!   received attention (when even the best token in a block draws
//!   little attention, the block is unimportant).
//!
//! PauTa low-outliers over the per-block αs mark the tokens that the
//! recomputation module must refresh (§3.3).

use crate::config::ProfileConfig;
use crate::tensor::{mean, powerlaw_fit, Tensor};

use super::pauta::pauta_low_outliers;

/// Per-document attention analytics.
#[derive(Debug, Clone)]
pub struct BlockAttention {
    pub n_layers: usize,
    pub n_blocks: usize,
    /// `[L][B]` doc-local index of the representative token.
    pub rep_token: Vec<Vec<usize>>,
    /// `[L][B]` power-law exponent (importance; lower = more important).
    pub alpha: Vec<Vec<f32>>,
    /// `[L][B]` mean received attention of the representative token
    /// (unimportance; lower = more unimportant).
    pub mean_received: Vec<Vec<f32>>,
    /// `[L][B]` importance rank (0 = most important, i.e. lowest α).
    pub importance_rank: Vec<Vec<usize>>,
    /// `[L]` doc-local token indices flagged for recomputation
    /// (representative tokens of PauTa-low-α middle blocks).
    pub outlier_tokens: Vec<Vec<usize>>,
}

impl BlockAttention {
    /// Middle block (exclusive of init/local) with max importance at
    /// layer `l` — the paper's `K_doc-i_max`.
    pub fn max_middle_block(&self, cfg: &ProfileConfig, l: usize)
                            -> Option<usize> {
        middle_range(cfg).min_by(|&a, &b| {
            self.alpha[l][a].partial_cmp(&self.alpha[l][b]).unwrap()
        })
    }

    /// Middle block with max *unimportance* at layer `l` (`K_doc-i_min`).
    pub fn min_middle_block(&self, cfg: &ProfileConfig, l: usize)
                            -> Option<usize> {
        middle_range(cfg).min_by(|&a, &b| {
            self.mean_received[l][a]
                .partial_cmp(&self.mean_received[l][b])
                .unwrap()
        })
    }
}

fn middle_range(cfg: &ProfileConfig)
                -> impl Iterator<Item = usize> + Clone {
    cfg.init_blocks..(cfg.blocks_per_doc - cfg.local_blocks)
}

/// Analyze one document's prefill attention `[L, H, Ld, Ld]`.
pub fn analyze_doc(attn: &Tensor, cfg: &ProfileConfig,
                   pauta_sigma: f32) -> BlockAttention {
    let (nl, nh, ld) = (cfg.n_layers, cfg.n_heads, cfg.doc_len);
    let bs = cfg.block_size;
    let nb = cfg.blocks_per_doc;
    debug_assert_eq!(attn.shape(), &[nl, nh, ld, ld]);

    let mut rep_token = vec![vec![0usize; nb]; nl];
    let mut alpha = vec![vec![0f32; nb]; nl];
    let mut mean_received = vec![vec![0f32; nb]; nl];
    let mut importance_rank = vec![vec![0usize; nb]; nl];
    let mut outlier_tokens = vec![Vec::new(); nl];

    for l in 0..nl {
        // received[t] = mean over heads and subsequent queries of attn[q,t]
        let mut received = vec![0f32; ld];
        for t in 0..ld {
            let n_q = ld - t - 1;
            if n_q == 0 {
                continue;
            }
            let mut acc = 0f32;
            for h in 0..nh {
                for q in (t + 1)..ld {
                    acc += attn.at(&[l, h, q, t]);
                }
            }
            received[t] = acc / (nh * n_q) as f32;
        }
        for b in 0..nb {
            let t0 = b * bs;
            let rep = (t0..t0 + bs)
                .max_by(|&a, &c| {
                    received[a].partial_cmp(&received[c]).unwrap()
                })
                .unwrap();
            rep_token[l][b] = rep;
            // received-attention curve of the representative token over
            // distance (the dashed curve of Fig. 7), head-averaged
            let mut curve = Vec::with_capacity(ld - rep);
            for q in (rep + 1)..ld {
                let mut acc = 0f32;
                for h in 0..nh {
                    acc += attn.at(&[l, h, q, rep]);
                }
                curve.push(acc / nh as f32);
            }
            if curve.is_empty() {
                // last token of the doc: nothing attends to it yet
                alpha[l][b] = f32::MAX;
                mean_received[l][b] = 0.0;
            } else {
                let (a, _) = powerlaw_fit(&curve);
                alpha[l][b] = a;
                mean_received[l][b] = mean(&curve);
            }
        }
        // importance rank: sort by alpha ascending
        let mut order: Vec<usize> = (0..nb).collect();
        order.sort_by(|&a, &c| {
            alpha[l][a].partial_cmp(&alpha[l][c]).unwrap()
        });
        for (rank, &b) in order.iter().enumerate() {
            importance_rank[l][b] = rank;
        }
        // PauTa low-α outliers among middle blocks -> recompute their
        // representative tokens at this layer
        let middle: Vec<usize> = middle_range(cfg).collect();
        let mid_alphas: Vec<f32> =
            middle.iter().map(|&b| alpha[l][b]).collect();
        for oi in pauta_low_outliers(&mid_alphas, pauta_sigma) {
            outlier_tokens[l].push(rep_token[l][middle[oi]]);
        }
    }

    BlockAttention {
        n_layers: nl,
        n_blocks: nb,
        rep_token,
        alpha,
        mean_received,
        importance_rank,
        outlier_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn tiny_cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"tiny","n_layers":1,"d_model":48,"n_heads":1,
                "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
                "block_size":8,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":4}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    /// Synthetic causal attention with a realistic shape: every token
    /// gets fast-decaying local attention (exp kernel), while `star`
    /// additionally receives strong slowly-decaying (power-law,
    /// exponent `alpha_star`) attention — the Fig.-7 "bright line".
    fn synthetic_attn(cfg: &ProfileConfig, star: usize, alpha_star: f32)
                      -> Tensor {
        let ld = cfg.doc_len;
        let mut a = Tensor::zeros(&[1, 1, ld, ld]);
        for q in 0..ld {
            let mut row = vec![0f32; ld];
            for (t, r) in row.iter_mut().enumerate().take(q + 1) {
                *r = (-((q - t) as f32) / 2.0).exp();
            }
            if q > star {
                row[star] += 2.0 * ((q - star) as f32).powf(-alpha_star);
            }
            let sum: f32 = row.iter().sum();
            for (t, &v) in row.iter().enumerate() {
                a.set(&[0, 0, q, t], v / sum);
            }
        }
        a
    }

    #[test]
    fn finds_representative_token_and_orders_alpha() {
        let cfg = tiny_cfg();
        // star token 12 lives in middle block 1 (tokens 8..16)
        let attn = synthetic_attn(&cfg, 12, 0.4);
        let ba = analyze_doc(&attn, &cfg, 3.0);
        assert_eq!(ba.rep_token[0][1], 12);
        // block 1 must be the most important middle block
        assert_eq!(ba.max_middle_block(&cfg, 0), Some(1));
        // slow power-law decay beats the exp-local kernel's fast decay
        assert!(ba.alpha[0][1] < ba.alpha[0][2],
                "alphas {:?}", ba.alpha[0]);
        // and it must out-rank the other middle block
        assert!(ba.importance_rank[0][1] < ba.importance_rank[0][2]);
    }

    #[test]
    fn unimportance_picks_weakest_block() {
        let cfg = tiny_cfg();
        let attn = synthetic_attn(&cfg, 12, 0.4);
        let ba = analyze_doc(&attn, &cfg, 3.0);
        // the starred block cannot be the most unimportant one
        let min = ba.min_middle_block(&cfg, 0).unwrap();
        assert_ne!(min, 1);
        assert!(ba.mean_received[0][min] < ba.mean_received[0][1]);
    }

    #[test]
    fn outliers_flag_the_star_token() {
        let cfg = tiny_cfg();
        let attn = synthetic_attn(&cfg, 12, 0.2);
        // low sigma so 2 middle blocks can yield an outlier
        let ba = analyze_doc(&attn, &cfg, 0.5);
        assert!(ba.outlier_tokens[0].contains(&12),
                "outliers {:?}", ba.outlier_tokens[0]);
    }

    #[test]
    fn rank_is_a_permutation() {
        let cfg = tiny_cfg();
        let attn = synthetic_attn(&cfg, 20, 1.0);
        let ba = analyze_doc(&attn, &cfg, 3.0);
        let mut ranks = ba.importance_rank[0].clone();
        ranks.sort_unstable();
        assert_eq!(ranks, (0..cfg.blocks_per_doc).collect::<Vec<_>>());
    }
}
