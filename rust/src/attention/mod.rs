//! Appendix-A attention analytics: dual-score block characterization
//! (power-law importance + sustained-attention unimportance), PauTa
//! outlier detection, and cross-layer stability scoring (N* selection).

pub mod analysis;
pub mod pauta;
pub mod stability;

pub use analysis::{analyze_doc, BlockAttention};
pub use pauta::{pauta_low_outliers, pauta_outliers};
pub use stability::{layer_stability_scores, select_stable_layers};
