//! Thin multi-node front end: one listener that places each serve
//! request on a cluster node and relays the reply stream verbatim.
//!
//! `samkv front --nodes addr0,addr1,… --port P` reuses the
//! cache-aware [`Router`] across **nodes** instead of engines: every
//! document hash in a request is advertised on its rendezvous owner's
//! residency slot ([`super::peers::rendezvous_owner`] — the same
//! ownership function the nodes' peer fetch uses), so
//! [`Router::pick`]'s residency stage sends doc-sharing requests to
//! the node that owns (or will own) their KV, its affinity stage keeps
//! a document set sticky when ownership ties, and least-loaded breaks
//! the rest. One placement logic, engine-level and cluster-level.
//!
//! # Degradation
//!
//! A node that fails a forward is marked down ([`Router::mark_down`] —
//! its residency advertisements clear) and the request retries on a
//! survivor, unless tokens were already relayed (the client saw
//! partial output; it gets a structured error, mirroring the engine
//! retry contract). With every node down the router falls back to
//! all nodes, so a recovered node is re-probed and marked back up on
//! its first success. `cmd:metrics` fans out to every live node and
//! returns the per-node replies with a `front` summary; `shutdown`
//! fans out and then stops the front end.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Router;
use crate::exec::ThreadPool;
use crate::json::{self, Value};
use crate::kvcache::doc_hash;
use crate::sync::Mutex;

use super::peers::rendezvous_owner;
use super::protocol::{self, Decoded, Request};

pub struct FrontEnd {
    ctx: FrontCtx,
}

#[derive(Clone)]
struct FrontCtx {
    nodes: Vec<String>,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    /// Document hashes already advertised on their owner's slot (the
    /// board dedupes, this just skips the lock on the hot path).
    seeded: Arc<Mutex<HashSet<u64>>>,
}

/// One lazily dialed upstream node connection.
struct Upstream {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Upstream {
    fn connect(addr: &str) -> Result<Upstream> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect node {addr}"))?;
        Ok(Upstream {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
        })
    }
}

impl FrontEnd {
    pub fn new(nodes: Vec<String>) -> FrontEnd {
        assert!(!nodes.is_empty(), "front end needs at least one node");
        let router = Arc::new(Router::new(nodes.len()));
        FrontEnd {
            ctx: FrontCtx {
                nodes,
                router,
                stop: Arc::new(AtomicBool::new(false)),
                seeded: Arc::new(Mutex::named("front-seeded",
                                              HashSet::new())),
            },
        }
    }

    /// The cluster router (tests observe placement/down state).
    pub fn router(&self) -> &Arc<Router> {
        &self.ctx.router
    }

    /// Serve until shutdown; same bind/callback contract as
    /// [`super::Server::run`].
    pub fn run(&self, addr: &str, on_bound: impl FnOnce(u16))
               -> Result<()> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        on_bound(listener.local_addr()?.port());
        let pool = ThreadPool::new(4, "front");
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        while !self.ctx.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let ctx = self.ctx.clone();
                    pool.execute(move || {
                        let _ = handle_conn(stream, &ctx);
                    });
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(
                        std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, ctx: &FrontCtx) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // per-connection upstream cache: requests on one client
    // connection are sequential, so one socket per node suffices
    let mut upstreams: Vec<Option<Upstream>> =
        (0..ctx.nodes.len()).map(|_| None).collect();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let line = line.trim().to_string();
        if line.is_empty() {
            continue;
        }
        let reply = match Request::decode(&line) {
            Ok(Decoded::Reply(v)) => v,
            Ok(Decoded::Request(Request::Serve(req))) => {
                match forward_serve(ctx, &mut upstreams, &line, &req,
                                    &mut writer)? {
                    Some(v) => v,
                    None => continue, // terminal line already relayed
                }
            }
            Ok(Decoded::Request(Request::Metrics)) => {
                fanout_cmd(ctx, &mut upstreams, &line, false)
            }
            Ok(Decoded::Request(Request::Shutdown)) => {
                let v = fanout_cmd(ctx, &mut upstreams, &line, true);
                ctx.stop.store(true, Ordering::Relaxed);
                v
            }
            Ok(Decoded::Request(Request::PeerGet { .. })) => {
                protocol::write_peer_miss(&mut writer,
                                          "front end holds no entries")?;
                continue;
            }
            Err(e) => protocol::error_reply(&format!("{e:#}")),
        };
        protocol::write_value(&mut writer, &reply)?;
        if ctx.stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Advertise each document hash on its rendezvous owner's residency
/// slot, once — this is what makes [`Router::pick`] owner-aware.
fn seed_ownership(ctx: &FrontCtx, req: &crate::coordinator::ServeRequest) {
    let mut seeded = ctx.seeded.lock();
    for doc in &req.sample.docs {
        let h = doc_hash(doc);
        if seeded.insert(h) {
            let owner = rendezvous_owner(h, ctx.nodes.len());
            ctx.router.residency_handle(owner).insert(h);
        }
    }
}

/// Forward one serve line, relaying every upstream line (token stream
/// included) to the client. Returns `Ok(None)` when the terminal line
/// was already relayed, `Ok(Some(reply))` when the caller must still
/// write a reply (the all-retries-failed error).
fn forward_serve(ctx: &FrontCtx, upstreams: &mut [Option<Upstream>],
                 line: &str, req: &crate::coordinator::ServeRequest,
                 client: &mut impl Write) -> Result<Option<Value>> {
    seed_ownership(ctx, req);
    let mut last_err = String::new();
    for _ in 0..ctx.nodes.len().max(1) {
        let idx = ctx.router.pick(&req.sample);
        let outcome = relay_once(ctx, upstreams, idx, line, client);
        ctx.router.done(idx);
        match outcome {
            Ok(()) => {
                ctx.router.mark_up(idx);
                return Ok(None);
            }
            Err(RelayError::Upstream(e)) => {
                // nothing reached the client yet — safe to retry on
                // a survivor
                if let Some(slot) = upstreams.get_mut(idx) {
                    *slot = None;
                }
                if ctx.router.mark_down(idx) {
                    crate::warn!("front: node {idx} marked down: {e:#}");
                }
                last_err = format!("{e:#}");
            }
            Err(RelayError::Client(e)) => return Err(e),
            Err(RelayError::MidStream(e)) => {
                // the client saw partial output: structured error,
                // mirroring the server's no-resubmit-after-token rule
                if let Some(slot) = upstreams.get_mut(idx) {
                    *slot = None;
                }
                if ctx.router.mark_down(idx) {
                    crate::warn!("front: node {idx} died mid-stream: \
                                  {e:#}");
                }
                return Ok(Some(Value::obj()
                    .set("id", req.id as i64)
                    .set("error",
                         format!("node failed mid-stream: {e:#}"))));
            }
        }
    }
    Ok(Some(Value::obj()
        .set("id", req.id as i64)
        .set("error",
             format!("all {} nodes failed: {last_err}",
                     ctx.nodes.len()))))
}

enum RelayError {
    /// Upstream failed before anything was relayed — retryable.
    Upstream(anyhow::Error),
    /// Upstream failed after token lines were relayed — terminal.
    MidStream(anyhow::Error),
    /// The client connection itself broke.
    Client(anyhow::Error),
}

/// Get (dialing if needed) the cached connection to node `idx`. An
/// out-of-range index reports as a connect failure, not a panic.
fn upstream_for<'a>(nodes: &[String],
                    upstreams: &'a mut [Option<Upstream>], idx: usize)
                    -> Result<&'a mut Upstream> {
    let addr = nodes
        .get(idx)
        .with_context(|| format!("node index {idx} out of range"))?;
    let slot = upstreams
        .get_mut(idx)
        .with_context(|| format!("node index {idx} out of range"))?;
    if slot.is_none() {
        *slot = Some(Upstream::connect(addr)?);
    }
    slot.as_mut()
        .with_context(|| format!("node {idx} connection missing"))
}

/// Write `line` to node `idx` and relay upstream lines until the
/// terminal one (the line without a `token` field).
fn relay_once(ctx: &FrontCtx, upstreams: &mut [Option<Upstream>],
              idx: usize, line: &str, client: &mut impl Write)
              -> std::result::Result<(), RelayError> {
    let up = upstream_for(&ctx.nodes, upstreams, idx)
        .map_err(RelayError::Upstream)?;
    writeln!(up.writer, "{line}")
        .map_err(|e| RelayError::Upstream(e.into()))?;
    let mut relayed = false;
    loop {
        let mut reply = String::new();
        let n = up.reader.read_line(&mut reply).map_err(|e| {
            if relayed {
                RelayError::MidStream(e.into())
            } else {
                RelayError::Upstream(e.into())
            }
        })?;
        if n == 0 {
            let e = anyhow::anyhow!("node closed mid-request");
            return Err(if relayed {
                RelayError::MidStream(e)
            } else {
                RelayError::Upstream(e)
            });
        }
        let v = json::parse(&reply).map_err(|e| {
            if relayed {
                RelayError::MidStream(e)
            } else {
                RelayError::Upstream(e)
            }
        })?;
        let terminal = v.get("token").is_none();
        client
            .write_all(reply.as_bytes())
            .map_err(|e| RelayError::Client(e.into()))?;
        if terminal {
            return Ok(());
        }
        relayed = true;
    }
}

/// Fan a command line out to every node, tolerating down nodes.
/// Returns the per-node replies plus a `front` summary object.
fn fanout_cmd(ctx: &FrontCtx, upstreams: &mut [Option<Upstream>],
              line: &str, best_effort: bool) -> Value {
    let mut replies = Vec::new();
    for idx in 0..ctx.nodes.len() {
        let one = (|| -> Result<Value> {
            let up = upstream_for(&ctx.nodes, upstreams, idx)?;
            writeln!(up.writer, "{line}")?;
            let mut reply = String::new();
            if up.reader.read_line(&mut reply)? == 0 {
                anyhow::bail!("node closed");
            }
            json::parse(&reply)
        })();
        replies.push(match one {
            Ok(v) => v,
            Err(e) => {
                if let Some(slot) = upstreams.get_mut(idx) {
                    *slot = None;
                }
                if !best_effort && ctx.router.mark_down(idx) {
                    crate::warn!("front: node {idx} marked down on \
                                  command fan-out: {e:#}");
                }
                Value::obj().set("error", format!("{e:#}"))
            }
        });
    }
    Value::obj()
        .set("front",
             Value::obj()
                 .set("nodes", ctx.nodes.len() as i64)
                 .set("down", ctx.router.n_down() as i64))
        .set("nodes", Value::Arr(replies))
}
