//! Multi-node host-tier sharding: the peer client side.
//!
//! With `--peers addr0,addr1,… --node-id I`, every node agrees on
//! document ownership by **rendezvous hashing** the content hash
//! against each node index ([`rendezvous_owner`]) — no coordination,
//! stable under node-set changes (removing one node only remaps the
//! documents it owned). On a local host+disk miss, the prefill
//! leaseholder asks the owning peer for the serialized entry image
//! (the checksummed disk-tier v3 format) over the owner's main
//! listener ([`super::protocol::Request::PeerGet`]) and decodes it
//! straight into the block pool — extending the exactly-once prefill
//! guarantee cluster-wide.
//!
//! # Degradation contract
//!
//! A peer fetch degrades exactly like a disk read: **any** failure —
//! connect refusal, timeout, truncated payload, checksum mismatch,
//! a well-formed miss, or an injected
//! [`crate::faultinject::FaultSite::PeerFetch`] fault — is a miss
//! that falls back to the local model prefill, never a failed
//! request. A transport-level failure additionally marks the peer
//! down for a cooldown window so back-to-back misses do not each pay
//! the connect timeout; the next fetch after the window probes it
//! again. All outcomes flow through [`crate::metrics::Metrics`]
//! (`peer_fetch_hits`/`peer_fetch_misses`, the fetch-latency
//! histogram, `peer_bytes_in`, and the `peers_down` gauge).

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::faultinject::{FaultPlan, FaultSite};
use crate::kvcache::PeerFetcher;
use crate::metrics::Metrics;
use crate::sync::Mutex;

use super::protocol::{self, Request};

/// How long a transport-failed peer stays marked down before the next
/// fetch probes it again.
pub const DEFAULT_PEER_DOWN_COOLDOWN_MS: u64 = 1000;

/// Rendezvous (highest-random-weight) owner of `hash` among `n_nodes`
/// node indexes. Every node computes this independently and agrees.
pub fn rendezvous_owner(hash: u64, n_nodes: usize) -> usize {
    assert!(n_nodes > 0);
    (0..n_nodes)
        .max_by_key(|&i| mix(hash, i as u64))
        .unwrap_or(0)
}

/// Stateless 64-bit mixer (splitmix64 finalizer) scoring one
/// (document, node) pair for rendezvous hashing.
fn mix(hash: u64, node: u64) -> u64 {
    let mut x = hash ^ node.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

/// The cluster view held by one node: peer addresses (indexed by node
/// id, including this node's own slot), fetch timeouts, per-peer down
/// state, and the metrics/fault-plan hooks. Implements
/// [`PeerFetcher`] so the host tier can consult it under the prefill
/// lease without depending on the server layer.
pub struct ClusterPeers {
    node_id: usize,
    addrs: Vec<String>,
    timeout: Duration,
    cooldown: Duration,
    faults: Option<Arc<FaultPlan>>,
    metrics: Arc<Metrics>,
    /// Per-peer down-until instant (transport failures only).
    down_until: Vec<Mutex<Option<Instant>>>,
}

impl ClusterPeers {
    /// `addrs[node_id]` is this node's own address (never dialed).
    pub fn new(node_id: usize, addrs: Vec<String>, timeout_ms: u64,
               metrics: Arc<Metrics>) -> ClusterPeers {
        assert!(node_id < addrs.len(),
                "--node-id {node_id} outside --peers list of {}",
                addrs.len());
        let down_until = (0..addrs.len())
            .map(|_| Mutex::named("peer-down", None))
            .collect();
        ClusterPeers {
            node_id,
            addrs,
            timeout: Duration::from_millis(timeout_ms.max(1)),
            cooldown: Duration::from_millis(DEFAULT_PEER_DOWN_COOLDOWN_MS),
            faults: None,
            metrics,
            down_until,
        }
    }

    /// Attach the active fault plan (the `peer_fetch` site).
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>)
                       -> ClusterPeers {
        self.faults = faults;
        self
    }

    /// Override the down-peer retry cooldown (tests).
    pub fn with_cooldown_ms(mut self, ms: u64) -> ClusterPeers {
        self.cooldown = Duration::from_millis(ms);
        self
    }

    pub fn node_id(&self) -> usize {
        self.node_id
    }

    pub fn n_nodes(&self) -> usize {
        self.addrs.len()
    }

    /// The agreed owner node of a document hash.
    pub fn owner_of(&self, hash: u64) -> usize {
        rendezvous_owner(hash, self.addrs.len())
    }

    fn is_down(&self, peer: usize) -> bool {
        let Some(slot) = self.down_until.get(peer) else {
            return false;
        };
        let guard = slot.lock();
        matches!(*guard, Some(until) if Instant::now() < until)
    }

    fn mark_down(&self, peer: usize) {
        if let Some(slot) = self.down_until.get(peer) {
            *slot.lock() = Some(Instant::now() + self.cooldown);
        }
        self.refresh_down_gauge();
    }

    fn mark_up(&self, peer: usize) {
        if let Some(slot) = self.down_until.get(peer) {
            *slot.lock() = None;
        }
        self.refresh_down_gauge();
    }

    fn refresh_down_gauge(&self) {
        let now = Instant::now();
        let down = self
            .down_until
            .iter()
            .filter(|m| matches!(*m.lock(),
                                 Some(until) if now < until))
            .count();
        self.metrics.peers_down.store(down as u64, Ordering::Relaxed);
    }

    fn miss(&self) {
        self.metrics.peer_fetch_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// One dial → peer_get → blob read against `owner`. `Ok(None)` is
    /// a well-formed miss (the peer is alive but does not hold the
    /// document); `Err` is a transport failure.
    fn try_fetch(&self, owner: usize, hash: u64, tokens: &[i32])
                 -> Result<Option<Vec<u8>>> {
        let addr_str = self
            .addrs
            .get(owner)
            .with_context(|| format!("peer index {owner} out of range"))?;
        let addr = addr_str
            .to_socket_addrs()
            .with_context(|| format!("resolve peer `{addr_str}`"))?
            .next()
            .with_context(|| format!("peer `{addr_str}` resolves to \
                                      nothing"))?;
        let stream = TcpStream::connect_timeout(&addr, self.timeout)
            .with_context(|| format!("connect peer {owner}"))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let msg = Request::PeerGet { hash, tokens: tokens.to_vec() }
            .encode();
        protocol::write_value(&mut writer, &msg)?;
        protocol::read_peer_reply(&mut reader)
    }
}

impl PeerFetcher for ClusterPeers {
    fn owner_is_remote(&self, hash: u64) -> bool {
        self.addrs.len() > 1 && self.owner_of(hash) != self.node_id
    }

    fn fetch(&self, hash: u64, tokens: &[i32]) -> Option<Vec<u8>> {
        let owner = self.owner_of(hash);
        if owner == self.node_id || self.addrs.len() < 2 {
            return None;
        }
        if self.is_down(owner) {
            // inside the cooldown window: fail fast, no dial
            self.miss();
            return None;
        }
        if let Some(plan) = &self.faults {
            // one site, two arms: the rule's `ms` is slept first
            // (latency), then the fetch fails as an injected miss
            if let Some(ms) = plan.latency_ms(FaultSite::PeerFetch) {
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                self.miss();
                return None;
            }
        }
        let start = Instant::now();
        match self.try_fetch(owner, hash, tokens) {
            Ok(Some(bytes)) => {
                self.mark_up(owner);
                self.metrics
                    .peer_fetch_hits
                    .fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .peer_bytes_in
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                self.metrics
                    .peer_fetch
                    .observe_ms(start.elapsed().as_secs_f64() * 1e3);
                Some(bytes)
            }
            Ok(None) => {
                // alive peer, honest miss — no down-marking
                self.mark_up(owner);
                self.miss();
                None
            }
            Err(e) => {
                crate::warn!("peer fetch from node {owner} failed \
                              (degrading to local prefill): {e:#}");
                self.mark_down(owner);
                self.miss();
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    #[test]
    fn rendezvous_is_stable_and_balanced() {
        // ownership only changes for documents whose owner left
        let mut moved = 0;
        let mut counts = [0usize; 4];
        for doc in 0..4000u64 {
            let h = mix(doc, 0xfeed); // spread the toy ids
            let o4 = rendezvous_owner(h, 4);
            counts[o4] += 1;
            let o3 = rendezvous_owner(h, 3);
            if o4 != 3 && o3 != o4 {
                moved += 1;
            }
        }
        assert_eq!(moved, 0,
                   "shrinking 4→3 nodes must only remap node 3's docs");
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 500, "node {i} owns {c} of 4000 — unbalanced");
        }
    }

    #[test]
    fn self_and_single_node_never_fetch() {
        let m = Arc::new(Metrics::new());
        let solo = ClusterPeers::new(0, vec!["127.0.0.1:1".into()], 50,
                                     Arc::clone(&m));
        assert!(!solo.owner_is_remote(123));
        assert!(solo.fetch(123, &[1, 2]).is_none());

        let duo = ClusterPeers::new(
            0,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            50,
            Arc::clone(&m),
        );
        // whatever this node owns is never remote
        let mine = (0..500u64)
            .find(|&h| rendezvous_owner(h, 2) == 0)
            .unwrap();
        assert!(!duo.owner_is_remote(mine));
        assert!(duo.fetch(mine, &[1]).is_none());
    }

    #[test]
    fn dead_peer_marks_down_and_cools_down() {
        let m = Arc::new(Metrics::new());
        // port 1 refuses instantly; cooldown long enough to observe
        let peers = ClusterPeers::new(
            0,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            50,
            Arc::clone(&m),
        )
        .with_cooldown_ms(60_000);
        let theirs = (0..500u64)
            .find(|&h| rendezvous_owner(h, 2) == 1)
            .unwrap();
        assert!(peers.owner_is_remote(theirs));
        assert!(peers.fetch(theirs, &[1, 2]).is_none());
        assert_eq!(m.peers_down.load(Ordering::Relaxed), 1);
        let misses = m.peer_fetch_misses.load(Ordering::Relaxed);
        assert!(misses >= 1);
        // second fetch short-circuits on the cooldown (still a miss)
        assert!(peers.fetch(theirs, &[1, 2]).is_none());
        assert_eq!(m.peer_fetch_misses.load(Ordering::Relaxed),
                   misses + 1);
        assert_eq!(m.peer_fetch_hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fault_plan_arm_fails_fetch_without_dialing() {
        let m = Arc::new(Metrics::new());
        let plan = Arc::new(
            crate::faultinject::FaultPlan::parse("peer_fetch:every=2")
                .unwrap(),
        );
        let peers = ClusterPeers::new(
            0,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            50,
            Arc::clone(&m),
        )
        .with_faults(Some(Arc::clone(&plan)));
        let theirs = (0..500u64)
            .find(|&h| rendezvous_owner(h, 2) == 1)
            .unwrap();
        // trial 1: rule does not fire (every=2) → real dial fails →
        // down; trial 2 would fire but the cooldown path runs first.
        assert!(peers.fetch(theirs, &[1]).is_none());
        peers.mark_up(1);
        assert!(peers.fetch(theirs, &[1]).is_none());
        assert_eq!(plan.injected(FaultSite::PeerFetch), 1,
                   "second trial must be the injected one");
    }
}
