//! JSON-lines-over-TCP serving front end + matching client.
//!
//! All framing/parse logic lives in [`protocol`] — one typed,
//! versioned encode/decode implementation shared by this server, the
//! [`Client`], and the peer RPC ([`peers`]). Wire format: one JSON
//! object per line.
//! Request:  `{"id":1,"docs":[[...]],"query":[...],"policy":"SamKV-fusion",
//!             "stream":true}`
//! Response: `{"id":1,"answer":[...],"ttft_ms":...,"plan_ms":...,
//!             "doc_prefill_ms":...,"seq_ratio":...}`
//! With `"stream":true`, one token line
//! `{"id":1,"index":0,"token":...}` is written per generated token
//! (SSE-style incremental output) before the final response line; the
//! terminal line is the one carrying `answer` (or `error`).
//! `{"cmd":"metrics"}` returns the metrics report (stamped with
//! `schema_version` — [`protocol::METRICS_SCHEMA_VERSION`]), per-engine
//! loads, the continuous-batching serving snapshot (`{"serving":{...}}`
//! — queue-wait/TTFT/e2e p50+p95, active-session count, fused decode
//! round counters, and the batched-dispatch gauges: `batched_rounds`,
//! `round_executions` / `executions_per_round`, `lane_occupancy`,
//! `assemble_overlap_ms`), and the per-tier document-cache counters
//! (`{"cache":{"host":{...},"resident":{...},"disk":{...}}}` — the
//! `disk` object carries the persistent tier's hits/misses/spills/
//! loads/corrupt/corrupt_blocks/collisions/evictions/bytes plus the
//! load-latency mean/p50/p95), and the KV block-pool snapshot
//! (`{"pool":{...}}` — slot gauges `slots_total`/`slots_live`/
//! `slots_free`/`slab_bytes` plus the monotone event counters
//! `grow_events`/`blocks_evicted`/`blocks_spilled`/`share_hits`/
//! `partial_evictions`/`double_frees`), and the KV codec snapshot
//! (`{"codec":{...}}` — active codec name, blocks encoded/decoded,
//! logical vs physical bytes with the achieved `compression_ratio`,
//! and the dequantization-latency mean/p50/p95), and the
//! fault/self-healing counters (`{"faults":{...}}` — per-site
//! injection totals plus retry/timeout/engine-down/circuit-breaker
//! accounting, see [`crate::faultinject`]), and the multi-node peer
//! counters (`{"peers":{...}}` — fetch hits/misses, latency p50/p95,
//! bytes shipped in/out, down-peer count, see [`peers`]);
//! `{"cmd":"shutdown"}` stops the listener.
//!
//! The same listener also answers the peer RPC
//! (`{"cmd":"peer_get",...}`, see [`protocol::Request::PeerGet`]):
//! when a host tier is attached ([`Server::with_host`]), a hit ships
//! the serialized disk-format entry image; any miss, mismatch, or
//! missing tier answers a structured peer-miss line. Unknown or
//! newer-versioned commands answer a structured `unsupported` reply
//! instead of dropping the connection.
//!
//! # Self-healing request path
//!
//! Each request line runs a bounded retry loop instead of a single
//! submit: the router picks an engine (skipping engines already marked
//! down), a known-dead engine (`EngineHandle::is_alive` false) is
//! marked down and re-picked before any work is spent, and a delivery
//! failure — the engine's reply channel dropping, or a structured
//! "decode thread died/unavailable" error — marks the engine down and
//! resubmits the request to a surviving engine after a jittered
//! backoff, up to `--request-retries` times. Requests that already
//! streamed token lines are never resubmitted (the client saw partial
//! output); they get the structured error. When `--request-timeout-ms`
//! is set, the whole loop — queue wait, admission, decode, retries —
//! runs under one deadline and returns a structured timeout error
//! instead of waiting unboundedly.
//!
//! A self-healing path must not itself panic: this tree is panic-free
//! outside tests (`tools/lint` denies `unwrap`/`expect`/`panic!`/
//! indexing; clippy denies `unwrap_used`/`expect_used` below), its
//! cross-thread state (`peer-down`, `front-seeded` lock classes) sits
//! on the [`crate::sync`] facade as order-leaves, and the lock-order
//! rules it inherits are documented in [`crate::kvcache`]'s
//! "Concurrency invariants" section.

// Serving-critical tree: see the doc note above.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod front;
pub mod peers;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DEFAULT_REQUEST_RETRIES, DEFAULT_RETRY_BACKOFF_MS};
use crate::coordinator::{
    EngineHandle, Router, ServeEvent, ServeRequest, ServeResponse,
};
use crate::exec::ThreadPool;
use crate::faultinject::FaultPlan;
use crate::json::{self, Value};
use crate::kvcache::{doc_hash, HostDocCache};
use crate::metrics::Metrics;
use crate::rng::Rng;

use protocol::{Decoded, Request};

pub struct Server {
    ctx: ConnCtx,
}

/// Everything one connection thread needs, cloned per accept.
#[derive(Clone)]
struct ConnCtx {
    engines: Vec<EngineHandle>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    /// Resubmission budget per request after a delivery failure.
    retries: usize,
    /// Base backoff between resubmissions (doubled per attempt, plus
    /// deterministic per-request jitter).
    backoff_ms: u64,
    /// End-to-end deadline per request; 0 = no deadline.
    timeout_ms: u64,
    /// Active fault plan, flushed into metrics on `cmd:metrics` so the
    /// wire always reports fresh injection counters.
    faults: Option<Arc<FaultPlan>>,
    /// Shared host tier, when attached — enables serving `peer_get`
    /// so this node can ship entries it owns to cluster peers.
    host: Option<Arc<HostDocCache>>,
}

impl Server {
    pub fn new(engines: Vec<EngineHandle>, metrics: Arc<Metrics>)
               -> Server {
        let router = Arc::new(Router::new(engines.len()));
        Self::with_router(engines, metrics, router)
    }

    /// Construct over an externally created router — the production
    /// wiring, where the router's residency board is shared with the
    /// engines' caches so placement can follow device residency.
    pub fn with_router(engines: Vec<EngineHandle>, metrics: Arc<Metrics>,
                       router: Arc<Router>) -> Server {
        assert_eq!(router.n_engines(), engines.len());
        Server {
            ctx: ConnCtx {
                engines,
                router,
                metrics,
                stop: Arc::new(AtomicBool::new(false)),
                retries: DEFAULT_REQUEST_RETRIES,
                backoff_ms: DEFAULT_RETRY_BACKOFF_MS,
                timeout_ms: 0,
                faults: None,
                host: None,
            },
        }
    }

    /// Configure the self-healing request path: `retries`
    /// resubmissions after delivery failures, `backoff_ms` base
    /// backoff between them, and a per-request `timeout_ms` deadline
    /// (0 disables the deadline).
    pub fn with_resilience(mut self, retries: usize, backoff_ms: u64,
                           timeout_ms: u64) -> Server {
        self.ctx.retries = retries;
        self.ctx.backoff_ms = backoff_ms;
        self.ctx.timeout_ms = timeout_ms;
        self
    }

    /// Attach the active fault plan so `cmd:metrics` replies carry
    /// fresh injection counters even between admission flushes.
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>)
                       -> Server {
        self.ctx.faults = faults;
        self
    }

    /// Attach the shared host tier so this listener answers the
    /// `peer_get` RPC — required for a node to serve its owned
    /// documents to `--peers` cluster members.
    pub fn with_host(mut self, host: Arc<HostDocCache>) -> Server {
        self.ctx.host = Some(host);
        self
    }

    /// Serve until a shutdown command arrives. Binds `addr` (e.g.
    /// "127.0.0.1:7070"); returns the bound port via the callback before
    /// blocking (useful with port 0 in tests).
    pub fn run(&self, addr: &str, on_bound: impl FnOnce(u16)) -> Result<()> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(false)?;
        on_bound(listener.local_addr()?.port());
        let pool = ThreadPool::new(4, "conn");
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        while !self.ctx.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let ctx = self.ctx.clone();
                    pool.execute(move || {
                        let _ = handle_conn(stream, &ctx);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break; // EOF
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Request::decode(&line) {
            // unknown/newer command: structured reply, keep the
            // connection — mixed-version peers negotiate down
            Ok(Decoded::Reply(v)) => v,
            Ok(Decoded::Request(Request::PeerGet { hash, tokens })) => {
                // blob framing: the handler writes the header (+ raw
                // payload on a hit) itself; no JSON reply line follows
                serve_peer_get(ctx, &mut writer, hash, &tokens)?;
                continue;
            }
            Ok(Decoded::Request(req)) => {
                match process_request(req, ctx, &mut writer) {
                    Ok(v) => v,
                    Err(e) => protocol::error_reply(&format!("{e:#}")),
                }
            }
            Err(e) => protocol::error_reply(&format!("{e:#}")),
        };
        protocol::write_value(&mut writer, &reply)?;
        if ctx.stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Answer one `peer_get`: ship the serialized entry when this node
/// holds the document (host tier, falling through to its disk tier),
/// a structured miss line otherwise. Misses here are normal — the
/// asking peer degrades to its own disk/prefill path.
fn serve_peer_get(ctx: &ConnCtx, writer: &mut impl Write, hash: u64,
                  tokens: &[i32]) -> Result<()> {
    let Some(host) = ctx.host.as_ref() else {
        protocol::write_peer_miss(writer, "no host tier attached")?;
        return Ok(());
    };
    if doc_hash(tokens) != hash {
        // collision or a confused peer: never serve mismatched KV
        protocol::write_peer_miss(writer, "hash mismatch")?;
        return Ok(());
    }
    match host.export_wire(hash, tokens) {
        Some(bytes) => {
            ctx.metrics
                .peer_bytes_out
                .fetch_add(bytes.len() as u64, Ordering::Relaxed);
            protocol::write_peer_hit(writer, hash, &bytes)?;
        }
        None => protocol::write_peer_miss(writer, "miss")?,
    }
    Ok(())
}

/// One serve attempt's outcome, as seen by the retry loop.
enum Attempt {
    /// Terminal reply for the client (success or non-retryable error).
    Done(Value),
    /// The `--request-timeout-ms` deadline passed.
    TimedOut,
    /// The engine failed to deliver (reply channel dropped, or a
    /// structured decode-thread-death error) before any token was
    /// streamed — safe to resubmit elsewhere.
    EngineFailure(String),
}

/// Errors that indicate the *engine* died rather than the request
/// being bad — the only failures worth resubmitting elsewhere.
fn is_engine_failure(msg: &str) -> bool {
    msg.contains("decode thread") || msg.contains("engine closed")
        || msg.contains("engine dropped reply")
}

/// Mark `idx` down in the router (clearing its residency
/// advertisements) and refresh the supervision counters.
fn mark_engine_down(ctx: &ConnCtx, idx: usize) {
    if ctx.router.mark_down(idx) {
        ctx.metrics.engine_down_events.fetch_add(1, Ordering::Relaxed);
        crate::warn!("server: engine-{idx} marked down \
                      ({} of {} down)",
                     ctx.router.n_down(), ctx.engines.len());
    }
    ctx.metrics
        .engines_down
        .store(ctx.router.n_down() as u64, Ordering::Relaxed);
}

/// Pick an engine for `req`, skipping engines whose decode thread is
/// already known dead (marking them down as discovered). The pick's
/// in-flight debit is held for the chosen engine only. Falls back to
/// the router's choice when every engine is down.
fn pick_live(ctx: &ConnCtx, req: &ServeRequest) -> usize {
    for _ in 0..ctx.engines.len() {
        let idx = ctx.router.pick(&req.sample);
        if ctx.engines.get(idx).is_some_and(|e| e.is_alive()) {
            return idx;
        }
        ctx.router.done(idx);
        mark_engine_down(ctx, idx);
    }
    ctx.router.pick(&req.sample)
}

/// Run one submit → event-drain attempt against engine `idx`. A
/// delivery failure becomes a resubmittable [`Attempt::EngineFailure`]
/// only while nothing was streamed yet; after the first streamed token
/// the client already saw partial output, so the failure is terminal.
fn serve_attempt(ctx: &ConnCtx, idx: usize, req: ServeRequest,
                 deadline: Option<Instant>, writer: &mut impl Write)
                 -> Result<Attempt> {
    let (req_id, stream_tokens) = (req.id, req.stream);
    let Some(engine) = ctx.engines.get(idx) else {
        return Ok(Attempt::EngineFailure(format!(
            "engine index {idx} out of range"
        )));
    };
    let events = match engine.submit(req) {
        Ok(rx) => rx,
        Err(e) => return Ok(Attempt::EngineFailure(format!("{e:#}"))),
    };
    let mut streamed = false;
    let dropped = |streamed: bool| {
        if streamed {
            Attempt::Done(error_line(req_id, "engine dropped reply"))
        } else {
            Attempt::EngineFailure("engine dropped reply".to_string())
        }
    };
    loop {
        let ev = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return Ok(Attempt::TimedOut);
                }
                match events.recv_timeout(d - now) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        return Ok(Attempt::TimedOut);
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Ok(dropped(streamed));
                    }
                }
            }
            None => match events.recv() {
                Ok(ev) => ev,
                Err(_) => return Ok(dropped(streamed)),
            },
        };
        match ev {
            ev @ ServeEvent::Token { .. } => {
                if stream_tokens {
                    writeln!(writer, "{}", ev.to_json())?;
                    streamed = true;
                }
            }
            ServeEvent::Done(resp) => {
                if !streamed
                    && resp.error.as_deref().is_some_and(is_engine_failure)
                {
                    return Ok(Attempt::EngineFailure(
                        resp.error.unwrap_or_default(),
                    ));
                }
                return Ok(Attempt::Done(resp.to_json()));
            }
        }
    }
}

/// Structured error line in the response schema.
fn error_line(id: u64, msg: &str) -> Value {
    ServeResponse {
        id,
        answer: vec![],
        stats: Default::default(),
        error: Some(msg.to_string()),
    }
    .to_json()
}

/// Handle one decoded request; streamed token lines are written to
/// `writer` as they arrive, and the returned value is the terminal
/// line (response or command result). `PeerGet` never reaches here —
/// its blob framing is handled in [`handle_conn`].
fn process_request(req: Request, ctx: &ConnCtx, writer: &mut impl Write)
                   -> Result<Value> {
    let req = match req {
        Request::Metrics => {
            if let Some(plan) = ctx.faults.as_deref() {
                ctx.metrics.record_faults(plan);
            }
            ctx.metrics.engines_down.store(
                ctx.router.n_down() as u64, Ordering::Relaxed);
            return Ok(Value::obj()
                .set("schema_version",
                     protocol::METRICS_SCHEMA_VERSION as i64)
                .set("report", ctx.metrics.report())
                .set("serving", ctx.metrics.serving_json())
                .set("cache", ctx.metrics.cache_tiers_json())
                .set("pool", ctx.metrics.pool_json())
                .set("codec", ctx.metrics.codec_json())
                .set("faults", ctx.metrics.faults_json())
                .set("peers", ctx.metrics.peers_json())
                .set("loads",
                     Value::Arr(ctx.router
                         .loads()
                         .iter()
                         .map(|&l| (l as i64).into())
                         .collect())));
        }
        Request::Shutdown => {
            ctx.stop.store(true, Ordering::Relaxed);
            return Ok(Value::obj().set("ok", true));
        }
        Request::PeerGet { .. } => {
            anyhow::bail!("peer_get reached process_request")
        }
        Request::Serve(req) => req,
    };
    let deadline = (ctx.timeout_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(ctx.timeout_ms));
    // deterministic per-request jitter: retries from requests that
    // failed together (one dead engine kills a whole wave) spread out
    // instead of thundering onto the survivor in lockstep
    let mut jitter = Rng::new(req.id ^ 0x5e1f_4ea1_0b5e_55ed);
    let mut attempt = 0usize;
    loop {
        let idx = pick_live(ctx, &req);
        let outcome = serve_attempt(ctx, idx, req.clone(), deadline,
                                    writer);
        ctx.router.done(idx);
        match outcome? {
            Attempt::Done(reply) => {
                if attempt > 0 && reply.get("error").is_none() {
                    ctx.metrics
                        .retry_successes
                        .fetch_add(1, Ordering::Relaxed);
                }
                return Ok(reply);
            }
            Attempt::TimedOut => {
                ctx.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                return Ok(error_line(
                    req.id,
                    &format!("request timed out after {}ms",
                             ctx.timeout_ms),
                ));
            }
            Attempt::EngineFailure(msg) => {
                mark_engine_down(ctx, idx);
                if attempt >= ctx.retries {
                    return Ok(error_line(
                        req.id,
                        &format!("engine failure after {attempt} \
                                  retries: {msg}"),
                    ));
                }
                attempt += 1;
                ctx.metrics.retries.fetch_add(1, Ordering::Relaxed);
                let base = ctx.backoff_ms
                    .saturating_mul(1 << (attempt - 1).min(6));
                let mut wait = base
                    + jitter.below((ctx.backoff_ms.max(1)) as usize)
                        as u64;
                if let Some(d) = deadline {
                    let left = d.saturating_duration_since(Instant::now());
                    wait = wait.min(left.as_millis() as u64);
                }
                if wait > 0 {
                    std::thread::sleep(Duration::from_millis(wait));
                }
            }
        }
    }
}

/// Minimal blocking client for examples, benches, and tests. Builds
/// every outbound line through [`protocol::Request::encode`] — the
/// same encoder the peer fetcher uses.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, msg: &Value) -> Result<Value> {
        protocol::write_value(&mut self.writer, msg)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line)
    }

    fn serve_value(&mut self, docs: &[Vec<i32>], query: &[i32],
                   policy: &str, stream: bool) -> Value {
        let id = self.next_id;
        self.next_id += 1;
        Request::Serve(ServeRequest {
            id,
            sample: crate::workload::Sample {
                docs: docs.to_vec(),
                query: query.to_vec(),
                answer: Vec::new(),
                qtype: "served".to_string(),
            },
            policy: policy.to_string(),
            stream,
        })
        .encode()
    }

    /// Serve one request; returns the parsed response object.
    pub fn request(&mut self, docs: &[Vec<i32>], query: &[i32],
                   policy: &str) -> Result<Value> {
        let msg = self.serve_value(docs, query, policy, false);
        self.roundtrip(&msg)
    }

    /// Serve one request with streaming: `on_token` fires for every
    /// token line as it arrives; returns the terminal response object.
    pub fn request_stream(&mut self, docs: &[Vec<i32>], query: &[i32],
                          policy: &str, mut on_token: impl FnMut(i32))
                          -> Result<Value> {
        let msg = self.serve_value(docs, query, policy, true);
        protocol::write_value(&mut self.writer, &msg)?;
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            let v = json::parse(&line)?;
            match v.get("token").and_then(|t| t.as_i64()) {
                Some(t) => on_token(t as i32),
                None => return Ok(v), // terminal line: answer or error
            }
        }
    }

    /// Send a raw command line (already JSON-encoded) and return the
    /// single reply line — the escape hatch for protocol tests.
    pub fn raw(&mut self, line: &Value) -> Result<Value> {
        self.roundtrip(line)
    }

    pub fn metrics(&mut self) -> Result<Value> {
        self.roundtrip(&Request::Metrics.encode())
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(&Request::Shutdown.encode())?;
        Ok(())
    }
}
