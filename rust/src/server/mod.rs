//! JSON-lines-over-TCP serving front end + matching client.
//!
//! Wire format: one JSON object per line.
//! Request:  `{"id":1,"docs":[[...]],"query":[...],"policy":"SamKV-fusion",
//!             "stream":true}`
//! Response: `{"id":1,"answer":[...],"ttft_ms":...,"plan_ms":...,
//!             "doc_prefill_ms":...,"seq_ratio":...}`
//! With `"stream":true`, one token line
//! `{"id":1,"index":0,"token":...}` is written per generated token
//! (SSE-style incremental output) before the final response line; the
//! terminal line is the one carrying `answer` (or `error`).
//! `{"cmd":"metrics"}` returns the metrics report, per-engine loads,
//! the continuous-batching serving snapshot (`{"serving":{...}}` —
//! queue-wait/TTFT/e2e p50+p95, active-session count, fused decode
//! round counters, and the batched-dispatch gauges: `batched_rounds`,
//! `round_executions` / `executions_per_round`, `lane_occupancy`,
//! `assemble_overlap_ms`), and the per-tier document-cache counters
//! (`{"cache":{"host":{...},"resident":{...},"disk":{...}}}` — the
//! `disk` object carries the persistent tier's hits/misses/spills/
//! loads/corrupt/corrupt_blocks/collisions/evictions/bytes plus the
//! load-latency mean/p50/p95), and the KV block-pool snapshot
//! (`{"pool":{...}}` — slot gauges `slots_total`/`slots_live`/
//! `slots_free`/`slab_bytes` plus the monotone event counters
//! `grow_events`/`blocks_evicted`/`blocks_spilled`/`share_hits`/
//! `partial_evictions`/`double_frees`), and the KV codec snapshot
//! (`{"codec":{...}}` — active codec name, blocks encoded/decoded,
//! logical vs physical bytes with the achieved `compression_ratio`,
//! and the dequantization-latency mean/p50/p95);
//! `{"cmd":"shutdown"}` stops the listener.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{EngineHandle, Router, ServeEvent, ServeRequest};
use crate::exec::ThreadPool;
use crate::json::{self, Value};
use crate::metrics::Metrics;

pub struct Server {
    engines: Vec<EngineHandle>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(engines: Vec<EngineHandle>, metrics: Arc<Metrics>)
               -> Server {
        let router = Arc::new(Router::new(engines.len()));
        Self::with_router(engines, metrics, router)
    }

    /// Construct over an externally created router — the production
    /// wiring, where the router's residency board is shared with the
    /// engines' caches so placement can follow device residency.
    pub fn with_router(engines: Vec<EngineHandle>, metrics: Arc<Metrics>,
                       router: Arc<Router>) -> Server {
        assert_eq!(router.n_engines(), engines.len());
        Server {
            engines,
            router,
            metrics,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Serve until a shutdown command arrives. Binds `addr` (e.g.
    /// "127.0.0.1:7070"); returns the bound port via the callback before
    /// blocking (useful with port 0 in tests).
    pub fn run(&self, addr: &str, on_bound: impl FnOnce(u16)) -> Result<()> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(false)?;
        on_bound(listener.local_addr()?.port());
        let pool = ThreadPool::new(4, "conn");
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let engines = self.engines.clone();
                    let router = Arc::clone(&self.router);
                    let metrics = Arc::clone(&self.metrics);
                    let stop = Arc::clone(&self.stop);
                    pool.execute(move || {
                        let _ = handle_conn(stream, &engines, &router,
                                            &metrics, &stop);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, engines: &[EngineHandle],
               router: &Router, metrics: &Metrics,
               stop: &AtomicBool) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match process_line(&line, engines, router, metrics,
                                       stop, &mut writer) {
            Ok(v) => v,
            Err(e) => Value::obj().set("error", format!("{e:#}")),
        };
        writeln!(writer, "{reply}")?;
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Handle one request line; streamed token lines are written to
/// `writer` as they arrive, and the returned value is the terminal
/// line (response or command result).
fn process_line(line: &str, engines: &[EngineHandle], router: &Router,
                metrics: &Metrics, stop: &AtomicBool,
                writer: &mut impl Write) -> Result<Value> {
    let v = json::parse(line)?;
    if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => Ok(Value::obj()
                .set("report", metrics.report())
                .set("serving", metrics.serving_json())
                .set("cache", metrics.cache_tiers_json())
                .set("pool", metrics.pool_json())
                .set("codec", metrics.codec_json())
                .set("loads",
                     Value::Arr(router
                         .loads()
                         .iter()
                         .map(|&l| (l as i64).into())
                         .collect()))),
            "shutdown" => {
                stop.store(true, Ordering::Relaxed);
                Ok(Value::obj().set("ok", true))
            }
            other => anyhow::bail!("unknown cmd `{other}`"),
        };
    }
    let req = ServeRequest::from_json(&v)?;
    let stream_tokens = req.stream;
    let idx = router.pick(&req.sample);
    let events = engines[idx].submit(req);
    let outcome = (|| -> Result<Value> {
        let events = events?;
        loop {
            match events.recv() {
                Ok(ev @ ServeEvent::Token { .. }) => {
                    if stream_tokens {
                        writeln!(writer, "{}", ev.to_json())?;
                    }
                }
                Ok(ServeEvent::Done(resp)) => return Ok(resp.to_json()),
                Err(_) => anyhow::bail!("engine dropped reply"),
            }
        }
    })();
    router.done(idx);
    outcome
}

/// Minimal blocking client for examples, benches, and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, msg: &Value) -> Result<Value> {
        writeln!(self.writer, "{msg}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line)
    }

    fn request_value(&mut self, docs: &[Vec<i32>], query: &[i32],
                     policy: &str, stream: bool) -> Value {
        let id = self.next_id;
        self.next_id += 1;
        let mut msg = Value::obj()
            .set("id", id as i64)
            .set("docs",
                 Value::Arr(docs
                     .iter()
                     .map(|d| {
                         Value::Arr(d.iter()
                             .map(|&t| (t as i64).into())
                             .collect())
                     })
                     .collect()))
            .set("query",
                 Value::Arr(query.iter().map(|&t| (t as i64).into()).collect()))
            .set("policy", policy);
        if stream {
            msg = msg.set("stream", true);
        }
        msg
    }

    /// Serve one request; returns the parsed response object.
    pub fn request(&mut self, docs: &[Vec<i32>], query: &[i32],
                   policy: &str) -> Result<Value> {
        let msg = self.request_value(docs, query, policy, false);
        self.roundtrip(&msg)
    }

    /// Serve one request with streaming: `on_token` fires for every
    /// token line as it arrives; returns the terminal response object.
    pub fn request_stream(&mut self, docs: &[Vec<i32>], query: &[i32],
                          policy: &str, mut on_token: impl FnMut(i32))
                          -> Result<Value> {
        let msg = self.request_value(docs, query, policy, true);
        writeln!(self.writer, "{msg}")?;
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            let v = json::parse(&line)?;
            match v.get("token").and_then(|t| t.as_i64()) {
                Some(t) => on_token(t as i32),
                None => return Ok(v), // terminal line: answer or error
            }
        }
    }

    pub fn metrics(&mut self) -> Result<Value> {
        self.roundtrip(&Value::obj().set("cmd", "metrics"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(&Value::obj().set("cmd", "shutdown"))?;
        Ok(())
    }
}
