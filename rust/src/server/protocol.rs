//! The typed, versioned wire protocol — one encode/decode
//! implementation shared by the user-facing server, the blocking
//! [`crate::server::Client`], and the peer RPC.
//!
//! # Framing
//!
//! Everything on the wire is a JSON object per line, except peer-fetch
//! payloads: a [`Request::PeerGet`] hit answers with one JSON header
//! line (`{"peer":{"ok":true,"hash":"…","len":N}}`) followed by
//! exactly `len` raw bytes — the checksummed disk-tier v3 entry image,
//! decoded straight into the receiver's block pool. Misses answer with
//! a single `{"peer":{"ok":false,…}}` line and no payload, so the peer
//! channel degrades to plain line framing.
//!
//! # Versioning
//!
//! Command messages may carry a `"v"` field (assumed
//! [`PROTOCOL_VERSION`] when absent). A newer version, or an unknown
//! `cmd`, decodes to a structured [`Decoded::Reply`] carrying an
//! `unsupported` object — listing this side's `protocol_version` and
//! `supported` commands — instead of an error that drops the
//! connection, so mixed-version clusters negotiate down gracefully.
//! Only malformed lines (unparseable JSON, bad serve bodies) are hard
//! errors.

use std::io::{BufRead, Read, Write};

use anyhow::{Context, Result};

use crate::coordinator::ServeRequest;
use crate::json::{self, Value};

/// Version spoken (and advertised in `unsupported` replies) by this
/// build. Bump on any wire-incompatible change.
pub const PROTOCOL_VERSION: u32 = 1;

/// Version of the `cmd:metrics` reply schema, carried as its
/// `schema_version` field so dashboards and CI can pin assertions.
/// v2 added `schema_version` itself and the top-level `peers` object.
pub const METRICS_SCHEMA_VERSION: u32 = 2;

/// Commands understood at [`PROTOCOL_VERSION`], advertised verbatim in
/// `unsupported` replies. A plain serve request (no `cmd` field) is
/// always understood.
pub const SUPPORTED_CMDS: [&str; 4] =
    ["metrics", "shutdown", "peer_get", "serve"];

/// Upper bound on one peer-fetch payload (1 GiB) — a sanity guard so
/// a corrupt or hostile header cannot make the receiver allocate
/// unboundedly.
pub const MAX_PEER_BLOB: usize = 1 << 30;

/// One decoded wire request.
#[derive(Debug)]
pub enum Request {
    /// A serve request line (the no-`cmd` form).
    Serve(ServeRequest),
    /// `{"cmd":"metrics"}` — the observability snapshot.
    Metrics,
    /// `{"cmd":"shutdown"}` — stop the listener.
    Shutdown,
    /// `{"cmd":"peer_get","hash":"<hex>","tokens":[…]}` — peer RPC:
    /// ship the serialized host/disk entry for this document. `hash`
    /// is the content hash as 16 hex digits (JSON numbers are f64 and
    /// cannot carry a u64 losslessly); `tokens` lets the owner verify
    /// against hash collisions before serving.
    PeerGet { hash: u64, tokens: Vec<i32> },
}

/// Outcome of decoding one line: a request to act on, or a structured
/// reply to write back as-is (the `unsupported` path).
#[derive(Debug)]
pub enum Decoded {
    Request(Request),
    Reply(Value),
}

impl Request {
    /// Decode one wire line. Unknown/newer commands are NOT errors:
    /// they decode to [`Decoded::Reply`] with an `unsupported` object.
    /// `Err` means the line itself was malformed (unparseable JSON or
    /// a bad serve body) and deserves an `error` reply.
    pub fn decode(line: &str) -> Result<Decoded> {
        let v = json::parse(line)?;
        let Some(cmd) = v.get("cmd").and_then(|c| c.as_str()) else {
            let req = ServeRequest::from_json(&v)?;
            return Ok(Decoded::Request(Request::Serve(req)));
        };
        let ver = v
            .get("v")
            .and_then(|x| x.as_i64())
            .map(|x| x as u32)
            .unwrap_or(PROTOCOL_VERSION);
        if ver > PROTOCOL_VERSION {
            return Ok(Decoded::Reply(unsupported_reply(cmd, Some(ver))));
        }
        match cmd {
            "metrics" => Ok(Decoded::Request(Request::Metrics)),
            "shutdown" => Ok(Decoded::Request(Request::Shutdown)),
            "peer_get" => {
                let hash = v
                    .get("hash")
                    .and_then(|h| h.as_str())
                    .and_then(|h| u64::from_str_radix(h, 16).ok())
                    .context("peer_get: missing/bad `hash`")?;
                let tokens = v
                    .req("tokens")?
                    .i32_vec()
                    .context("peer_get: bad `tokens`")?;
                Ok(Decoded::Request(Request::PeerGet { hash, tokens }))
            }
            other => Ok(Decoded::Reply(unsupported_reply(other, None))),
        }
    }

    /// Encode this request as its wire line — the single builder used
    /// by the client and the peer fetcher (round-trips with
    /// [`Request::decode`]).
    pub fn encode(&self) -> Value {
        match self {
            Request::Serve(req) => {
                let mut msg = Value::obj()
                    .set("id", req.id as i64)
                    .set("docs",
                         Value::Arr(req.sample.docs
                             .iter()
                             .map(|d| {
                                 Value::Arr(d.iter()
                                     .map(|&t| (t as i64).into())
                                     .collect())
                             })
                             .collect()))
                    .set("query",
                         Value::Arr(req.sample.query
                             .iter()
                             .map(|&t| (t as i64).into())
                             .collect()));
                if !req.policy.is_empty() {
                    msg = msg.set("policy", req.policy.as_str());
                }
                if req.stream {
                    msg = msg.set("stream", true);
                }
                msg
            }
            Request::Metrics => Value::obj().set("cmd", "metrics"),
            Request::Shutdown => Value::obj().set("cmd", "shutdown"),
            Request::PeerGet { hash, tokens } => Value::obj()
                .set("cmd", "peer_get")
                .set("v", PROTOCOL_VERSION as i64)
                .set("hash", format!("{hash:016x}"))
                .set("tokens",
                     Value::Arr(tokens
                         .iter()
                         .map(|&t| (t as i64).into())
                         .collect())),
        }
    }
}

/// The structured reply for an unknown or newer-version command.
pub fn unsupported_reply(cmd: &str, got_version: Option<u32>) -> Value {
    let mut u = Value::obj()
        .set("cmd", cmd)
        .set("protocol_version", PROTOCOL_VERSION as i64)
        .set("supported",
             Value::Arr(SUPPORTED_CMDS.iter().map(|&c| c.into()).collect()));
    if let Some(v) = got_version {
        u = u.set("got_version", v as i64);
    }
    Value::obj().set("unsupported", u)
}

/// The structured reply for a malformed line.
pub fn error_reply(msg: &str) -> Value {
    Value::obj().set("error", msg)
}

/// Write one JSON line (the universal reply/request framing).
pub fn write_value(w: &mut impl Write, v: &Value) -> std::io::Result<()> {
    writeln!(w, "{v}")
}

/// Peer-fetch hit: the JSON header line followed by the raw entry
/// image. Flushes so the bytes hit the socket with the header.
pub fn write_peer_hit(w: &mut impl Write, hash: u64, payload: &[u8])
                      -> std::io::Result<()> {
    let header = Value::obj().set(
        "peer",
        Value::obj()
            .set("ok", true)
            .set("hash", format!("{hash:016x}"))
            .set("len", payload.len() as i64),
    );
    writeln!(w, "{header}")?;
    w.write_all(payload)?;
    w.flush()
}

/// Peer-fetch miss: a single header line, no payload.
pub fn write_peer_miss(w: &mut impl Write, reason: &str)
                       -> std::io::Result<()> {
    let header = Value::obj().set(
        "peer",
        Value::obj().set("ok", false).set("reason", reason),
    );
    writeln!(w, "{header}")
}

/// Read one peer-fetch reply: `Ok(Some(bytes))` on a hit,
/// `Ok(None)` on a well-formed miss, `Err` on a broken stream or a
/// header that fails the [`MAX_PEER_BLOB`] sanity bound.
pub fn read_peer_reply(r: &mut impl BufRead) -> Result<Option<Vec<u8>>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        anyhow::bail!("peer closed before reply header");
    }
    let v = json::parse(&line)?;
    let peer = v.req("peer")?;
    if !peer.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
        return Ok(None);
    }
    let len = peer
        .get("len")
        .and_then(|l| l.as_usize())
        .context("peer reply: missing/bad `len`")?;
    if len > MAX_PEER_BLOB {
        anyhow::bail!("peer reply len {len} exceeds sanity bound");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("peer payload truncated")?;
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Sample;
    use std::io::{BufReader, Cursor};

    #[test]
    fn serve_round_trip() {
        let req = ServeRequest {
            id: 42,
            sample: Sample {
                docs: vec![vec![1, 2, 3], vec![4, 5]],
                query: vec![9, 8, 7],
                answer: Vec::new(),
                qtype: "served".to_string(),
            },
            policy: "SamKV-fusion".to_string(),
            stream: true,
        };
        let line = Request::Serve(req).encode().to_string();
        match Request::decode(&line).unwrap() {
            Decoded::Request(Request::Serve(back)) => {
                assert_eq!(back.id, 42);
                assert_eq!(back.sample.docs,
                           vec![vec![1, 2, 3], vec![4, 5]]);
                assert_eq!(back.sample.query, vec![9, 8, 7]);
                assert_eq!(back.policy, "SamKV-fusion");
                assert!(back.stream);
            }
            other => panic!("expected serve, got {other:?}"),
        }
    }

    #[test]
    fn command_round_trips() {
        for (req, want) in [
            (Request::Metrics, "metrics"),
            (Request::Shutdown, "shutdown"),
        ] {
            let line = req.encode().to_string();
            match Request::decode(&line).unwrap() {
                Decoded::Request(Request::Metrics) => {
                    assert_eq!(want, "metrics")
                }
                Decoded::Request(Request::Shutdown) => {
                    assert_eq!(want, "shutdown")
                }
                other => panic!("bad decode {other:?}"),
            }
        }
    }

    #[test]
    fn peer_get_round_trip_preserves_full_u64_hash() {
        // a hash above 2^53 would be mangled by f64 JSON numbers; the
        // hex-string encoding must carry it losslessly
        let hash = 0xdead_beef_cafe_f00du64;
        let line = Request::PeerGet { hash, tokens: vec![3, 1, 4] }
            .encode()
            .to_string();
        match Request::decode(&line).unwrap() {
            Decoded::Request(Request::PeerGet { hash: h, tokens }) => {
                assert_eq!(h, hash);
                assert_eq!(tokens, vec![3, 1, 4]);
            }
            other => panic!("expected peer_get, got {other:?}"),
        }
    }

    #[test]
    fn unknown_cmd_is_structured_unsupported_not_error() {
        let d = Request::decode(r#"{"cmd":"fancy_new_thing"}"#).unwrap();
        let Decoded::Reply(v) = d else {
            panic!("expected unsupported reply")
        };
        let u = v.req("unsupported").unwrap();
        assert_eq!(u.get("cmd").and_then(|c| c.as_str()),
                   Some("fancy_new_thing"));
        assert_eq!(u.get("protocol_version").and_then(|p| p.as_i64()),
                   Some(PROTOCOL_VERSION as i64));
        let sup = u.get("supported").and_then(|s| s.as_arr()).unwrap();
        assert!(sup.iter().any(|c| c.as_str() == Some("peer_get")));
    }

    #[test]
    fn newer_version_is_unsupported_with_got_version() {
        let line = format!(r#"{{"cmd":"metrics","v":{}}}"#,
                           PROTOCOL_VERSION + 1);
        let Decoded::Reply(v) = Request::decode(&line).unwrap() else {
            panic!("newer version must be unsupported, not served")
        };
        let u = v.req("unsupported").unwrap();
        assert_eq!(u.get("got_version").and_then(|g| g.as_i64()),
                   Some((PROTOCOL_VERSION + 1) as i64));
    }

    #[test]
    fn malformed_lines_are_errors() {
        assert!(Request::decode("not json").is_err());
        assert!(Request::decode(r#"{"id":1,"query":[1]}"#).is_err(),
                "serve body without docs must be a hard error");
        assert!(Request::decode(r#"{"cmd":"peer_get","tokens":[1]}"#)
                    .is_err(),
                "peer_get without hash must be a hard error");
    }

    #[test]
    fn peer_blob_round_trip() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let mut wire = Vec::new();
        write_peer_hit(&mut wire, 0xabcd, &payload).unwrap();
        let mut r = BufReader::new(Cursor::new(wire));
        let got = read_peer_reply(&mut r).unwrap();
        assert_eq!(got.as_deref(), Some(&payload[..]));

        let mut wire = Vec::new();
        write_peer_miss(&mut wire, "not owner").unwrap();
        let mut r = BufReader::new(Cursor::new(wire));
        assert_eq!(read_peer_reply(&mut r).unwrap(), None);
    }

    #[test]
    fn peer_reply_rejects_truncation_and_bad_headers() {
        let mut wire = Vec::new();
        write_peer_hit(&mut wire, 1, &[1, 2, 3, 4]).unwrap();
        wire.truncate(wire.len() - 2); // lose payload bytes
        let mut r = BufReader::new(Cursor::new(wire));
        assert!(read_peer_reply(&mut r).is_err());

        let mut r = BufReader::new(Cursor::new(Vec::<u8>::new()));
        assert!(read_peer_reply(&mut r).is_err(), "EOF before header");
    }
}
