//! Regenerates Fig. 1: TTFT (% of full recompute) vs F1, KV memory as
//! the circle size, for all seven methods.
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    let n = args.get::<usize>("samples", 12);
    let model = exp::load_model(&profile).expect("artifacts built?");
    let ds = exp::load_dataset(&model, &args.get_str("dataset",
                                                     "hotpot-sim"))
        .unwrap();
    exp::fig1(&model, &ds, n).unwrap();
}
