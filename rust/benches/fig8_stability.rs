//! Regenerates Fig. 8: per-layer attention-stability scores for every
//! dataset (the N* selection evidence).
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    let model = exp::load_model(&profile).expect("artifacts built?");
    exp::fig8(&model, args.get::<usize>("docs", 16)).unwrap();
}
