//! End-to-end serving throughput/latency under synthetic load through
//! the full coordinator stack (continuous-batching engine threads over
//! the shared host doc-cache tier, cache-aware router, batcher,
//! metrics), swept over admission-wave size (`--batch-sizes`) × open
//! loop arrival rate (`--rates`, requests/sec, 0 = as fast as
//! possible), with recurring document sets exercising both cache
//! tiers. Each sweep row in the emitted JSON carries tokens/sec, TTFT
//! p50/p95, queue-wait p50/p95, the fused decode-round counters, and
//! the per-tier hit/miss/eviction/publish counters (host, resident,
//! and the persistent disk tier, plus the KV codec counters under
//! `--kv-codec`/`--kv-hot-blocks`); with `--engines 2+`,
//! `host_publishes == unique documents` demonstrates the cross-engine
//! prefill dedup. The emitted `restart` object carries a
//! cold-vs-warm-start pair over a disk cache directory
//! (`warm_doc_prefills == 0` demonstrates the zero-prefill restart,
//! `warm_matches_cold` the token-identical lossless warm path), and
//! `restart_codecs` repeats the pair once per KV encoding
//! (f32/f16/int8) so the warm-restart I/O saving
//! (`warm_disk_bytes_loaded`) is measured per codec.
use samkv::bench::experiments as exp;
use samkv::cli::Args;
use samkv::config::{KvCodecKind, ServingConfig};

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    let batch_sizes =
        exp::parse_list::<usize>(&args.get_str("batch-sizes", "1,4"))
            .expect("--batch-sizes");
    let rates = exp::parse_list::<f64>(&args.get_str("rates", "0,32"))
        .expect("--rates");
    let defaults = ServingConfig::default();
    let codec = args.get_str("kv-codec", defaults.kv_codec.name())
        .parse::<KvCodecKind>()
        .expect("--kv-codec");
    let hot_blocks =
        args.get::<usize>("kv-hot-blocks", defaults.kv_hot_blocks);
    for policy in args.get_str("policies",
                               "SamKV-fusion,CacheBlend,Reuse").split(',') {
        exp::throughput(&profile, policy,
                        args.get::<usize>("requests", 24),
                        args.get::<usize>("unique", 8),
                        args.get::<usize>("engines", 2),
                        &batch_sizes, &rates, codec, hot_blocks)
            .unwrap();
    }
}
