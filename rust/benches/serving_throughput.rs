//! End-to-end serving throughput/latency under synthetic load through
//! the full coordinator stack (continuous-batching engine threads over
//! the shared host doc-cache tier, cache-aware router, batcher,
//! metrics), swept over admission-wave size (`--batch-sizes`) × open
//! loop arrival rate (`--rates`, requests/sec, 0 = as fast as
//! possible), with recurring document sets exercising both cache
//! tiers. Each sweep row in the emitted JSON carries tokens/sec, TTFT
//! p50/p95, queue-wait p50/p95, the fused decode-round counters, and
//! the per-tier hit/miss/eviction/publish counters (host, resident,
//! and the persistent disk tier); with `--engines 2+`,
//! `host_publishes == unique documents` demonstrates the cross-engine
//! prefill dedup, and the emitted `restart` object carries a
//! cold-vs-warm-start pair over a disk cache directory
//! (`warm_doc_prefills == 0` demonstrates the zero-prefill restart).
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    let batch_sizes =
        exp::parse_list::<usize>(&args.get_str("batch-sizes", "1,4"))
            .expect("--batch-sizes");
    let rates = exp::parse_list::<f64>(&args.get_str("rates", "0,32"))
        .expect("--rates");
    for policy in args.get_str("policies",
                               "SamKV-fusion,CacheBlend,Reuse").split(',') {
        exp::throughput(&profile, policy,
                        args.get::<usize>("requests", 24),
                        args.get::<usize>("unique", 8),
                        args.get::<usize>("engines", 2),
                        &batch_sizes, &rates)
            .unwrap();
    }
}
