//! End-to-end serving throughput/latency under synthetic load through
//! the full coordinator stack (engine threads over the shared host
//! doc-cache tier, cache-aware router, batcher, metrics), with
//! recurring document sets exercising both cache tiers. The emitted
//! JSON carries the per-tier hit/miss/eviction/publish counters; with
//! `--engines 2+`, `host_publishes == unique documents` demonstrates
//! the cross-engine prefill dedup.
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    for policy in args.get_str("policies",
                               "SamKV-fusion,CacheBlend,Reuse").split(',') {
        exp::throughput(&profile, policy,
                        args.get::<usize>("requests", 24),
                        args.get::<usize>("unique", 8),
                        args.get::<usize>("engines", 2))
            .unwrap();
    }
}
