//! Regenerates Fig. 7: per-block representative-token attention curves
//! fitted with power laws; α ordering defines block importance.
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "s4");
    let model = exp::load_model(&profile).expect("artifacts built?");
    let ds = exp::load_dataset(&model, &args.get_str("dataset",
                                                     "hotpot-sim"))
        .unwrap();
    exp::fig7(&model, &ds, args.get::<usize>("docs", 16)).unwrap();
}
