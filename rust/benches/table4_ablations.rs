//! Regenerates Table 4: SamKV ablations (selection / personalized bias /
//! recomputation) across the four datasets, fusion update.
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let n = args.get::<usize>("samples", 10);
    for profile in args.get_str("profiles", "s4,m6").split(',') {
        match exp::load_model(profile) {
            Ok(model) => {
                exp::table4(&model, n).unwrap();
            }
            Err(e) => eprintln!("skipping {profile}: {e:#}"),
        }
    }
}
