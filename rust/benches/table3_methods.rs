//! Regenerates Table 3: F1 of all methods across the three QA datasets
//! for each trained model profile.
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let n = args.get::<usize>("samples", 12);
    for profile in args.get_str("profiles", "s4,m6").split(',') {
        match exp::load_model(profile) {
            Ok(model) => {
                exp::table3(&model, n).unwrap();
            }
            Err(e) => eprintln!("skipping {profile}: {e:#}"),
        }
    }
}
