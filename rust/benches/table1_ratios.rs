//! Regenerates Table 1: sequence ratio / recomputation ratio for the
//! multi-context methods (CacheBlend, EPIC, SamKV).
//! Run: `cargo bench --bench table1_ratios [-- --profile s4 --samples N]`
use samkv::bench::experiments as exp;
use samkv::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let profile = args.get_str("profile", "x16");
    let n = args.get::<usize>("samples", 10);
    let model = exp::load_model(&profile).expect("artifacts built?");
    let ds = exp::load_dataset(&model, &args.get_str("dataset",
                                                     "hotpot-sim"))
        .unwrap();
    exp::table1(&model, &ds, n).unwrap();
}
